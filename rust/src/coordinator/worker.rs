//! Worker layer: per-replica state and the pluggable inner optimizer.
//!
//! Each of the K logical DiLoCo workers owns a full parameter replica,
//! inner optimizer state, an independent data shard and an error-
//! feedback accumulator.  The `WorkerPool` runs the K inner loops on
//! **K persistent executor threads** ("lanes") attached for the whole
//! training run (`WorkerPool::scoped`): each step the pool moves every
//! worker's state to its lane over a channel, the lane runs the inner
//! step, and the pool collects `(worker, loss)` back in lane order — a
//! channel-based step barrier.  Between steps the main thread owns all
//! worker state, so the sync boundary needs no locking.  This replaces
//! the per-step `thread::scope` spawn of the first parallel engine
//! (thread churn that was measurable on nano-scale sweeps).
//!
//! Determinism contract: every worker draws from its own RNG stream
//! (`corpus.shard(w)`), the per-step losses are reduced in worker-index
//! order after the barrier, and the sync engine fixes the reduction
//! order at its own barrier — so a parallel run is bit-for-bit
//! identical to the sequential reference path
//! (tests/parallel_determinism.rs).

use std::sync::mpsc;
use std::thread;

use anyhow::Result;

use super::config::Method;
use super::diloco::accumulate_grads_into;
use super::sync::SyncTensorMeta;
use crate::compress::{CompressorSet, ErrorFeedback};
use crate::data::{Corpus, Shard};
use crate::obs;
use crate::runtime::{Session, Tensors};

/// The per-step parameter/state update applied inside every worker
/// (Algorithm 1 line 8).  Implementations are stateless dispatchers to
/// the session's compiled executables — all optimizer state lives in
/// the worker, so a single instance serves all K replicas from any
/// thread.
pub trait InnerOptimizer: Send + Sync {
    fn name(&self) -> &'static str;

    /// Fresh zero state shaped for this optimizer.
    fn zero_state(&self, sess: &Session) -> Tensors;

    /// One optimizer step: (params, state, grads) -> (params', state').
    #[allow(clippy::too_many_arguments)]
    fn step(
        &self,
        sess: &Session,
        params: &Tensors,
        state: &Tensors,
        grads: &Tensors,
        t: f32,
        lr: f32,
        wd: f32,
    ) -> Result<(Tensors, Tensors)>;

    /// [`step`](InnerOptimizer::step) updating `params`/`state` in
    /// place — same math, no output clones; what the steady-state inner
    /// loop runs.  The default delegates to the allocating form, so
    /// third-party optimizers stay correct unchanged; the built-in
    /// optimizers override it with the session's in-place entry points.
    #[allow(clippy::too_many_arguments)]
    fn step_in_place(
        &self,
        sess: &Session,
        params: &mut Tensors,
        state: &mut Tensors,
        grads: &Tensors,
        t: f32,
        lr: f32,
        wd: f32,
    ) -> Result<()> {
        let (p, s) = self.step(sess, params, state, grads, t, lr, wd)?;
        *params = p;
        *state = s;
        Ok(())
    }
}

/// AdamW inner optimizer (DiLoCo / DP-AdamW).
pub struct AdamWInner;

impl InnerOptimizer for AdamWInner {
    fn name(&self) -> &'static str {
        "adamw"
    }

    fn zero_state(&self, sess: &Session) -> Tensors {
        sess.zero_adamw_state()
    }

    fn step(
        &self,
        sess: &Session,
        params: &Tensors,
        state: &Tensors,
        grads: &Tensors,
        t: f32,
        lr: f32,
        wd: f32,
    ) -> Result<(Tensors, Tensors)> {
        sess.apply_adamw(params, state, grads, t, lr, wd)
    }

    fn step_in_place(
        &self,
        sess: &Session,
        params: &mut Tensors,
        state: &mut Tensors,
        grads: &Tensors,
        t: f32,
        lr: f32,
        wd: f32,
    ) -> Result<()> {
        sess.apply_adamw_in_place(params, state, grads, t, lr, wd)
    }
}

/// Muon inner optimizer (MuLoCo / DP-Muon): Newton–Schulz
/// orthogonalized momentum on hidden matrices, AdamW elsewhere
/// (routing comes from the manifest).  `ns_iters` is the Newton-Schulz
/// iteration count (`TrainConfig::ns_iters` / CLI `--ns-iters`); the
/// native backend honors any count, PJRT only the baked-in default.
///
/// `ortho_interval` is the MuonBP-style block-periodic schedule
/// (Khaled et al.): orthogonalize on steps where
/// `(t - 1) % r == 0` and fall back to normalized momentum SGD
/// (`ns_iters = 0`) on the steps between, amortizing the Newton-Schulz
/// cost over r inner steps.  `r = 1` takes the exact pre-knob code
/// path — every step orthogonalizes with `ns_iters` — so it is
/// bit-identical to classic Muon (closed-form test below).
pub struct MuonInner {
    pub ns_iters: usize,
    pub ortho_interval: usize,
}

impl MuonInner {
    /// Newton-Schulz depth for global step `t` under the block-periodic
    /// schedule.
    fn ns_at(&self, t: f32) -> usize {
        if self.ortho_interval <= 1 {
            return self.ns_iters;
        }
        let step = (t as u64).max(1);
        if (step - 1) % self.ortho_interval as u64 == 0 {
            self.ns_iters
        } else {
            0
        }
    }
}

impl InnerOptimizer for MuonInner {
    fn name(&self) -> &'static str {
        "muon"
    }

    fn zero_state(&self, sess: &Session) -> Tensors {
        sess.zero_muon_state()
    }

    fn step(
        &self,
        sess: &Session,
        params: &Tensors,
        state: &Tensors,
        grads: &Tensors,
        t: f32,
        lr: f32,
        wd: f32,
    ) -> Result<(Tensors, Tensors)> {
        sess.apply_muon_ns(params, state, grads, t, lr, wd, self.ns_at(t))
    }

    fn step_in_place(
        &self,
        sess: &Session,
        params: &mut Tensors,
        state: &mut Tensors,
        grads: &Tensors,
        t: f32,
        lr: f32,
        wd: f32,
    ) -> Result<()> {
        sess.apply_muon_ns_in_place(params, state, grads, t, lr, wd,
                                    self.ns_at(t))
    }
}

/// Inner-optimizer dispatch from the configured method.  `ns_iters` is
/// the Muon Newton-Schulz depth (`NS_STEPS` for the paper's setting)
/// and `ortho_interval` the block-periodic schedule (1 = every step);
/// both are ignored by AdamW methods.  The single dispatch point, so
/// every caller (train loop, probes) agrees on the optimizer's knobs.
pub fn inner_with(
    method: Method,
    ns_iters: usize,
    ortho_interval: usize,
) -> Box<dyn InnerOptimizer> {
    if method.uses_muon() {
        Box::new(MuonInner { ns_iters, ortho_interval })
    } else {
        Box::new(AdamWInner)
    }
}

/// Per-worker replica state (Algorithm 1's theta_k / inner state /
/// D_k shard, plus the Algorithm 2 error-feedback accumulator).
pub struct Worker<'c> {
    pub params: Tensors,
    pub opt_state: Tensors,
    pub shard: Shard<'c>,
    pub ef: ErrorFeedback,
    // step scratch, lazily shaped on the first inner step and reused
    // for the rest of the run: the grad accumulator, the per-microbatch
    // grad staging set, and the token staging buffer.  Together with
    // the backend's arena these make the warmed inner step
    // allocation-free (tests/alloc_steady.rs pins it).
    grads: Tensors,
    micro_grads: Tensors,
    tok: Vec<i32>,
}

impl<'c> Worker<'c> {
    pub fn new(
        params: Tensors,
        opt_state: Tensors,
        shard: Shard<'c>,
        ef: ErrorFeedback,
    ) -> Worker<'c> {
        Worker {
            params,
            opt_state,
            shard,
            ef,
            grads: Tensors::new(),
            micro_grads: Tensors::new(),
            tok: Vec::new(),
        }
    }

    /// One inner step: accumulate grads over this worker's batch slice
    /// and apply the inner optimizer.  Returns the mean micro-loss.
    /// All tensor traffic runs through the worker's step scratch and
    /// the in-place optimizer entry points — after the first (warming)
    /// step no heap allocation happens here.
    pub fn inner_step(
        &mut self,
        sess: &Session,
        inner: &dyn InnerOptimizer,
        batch_seqs: usize,
        t: f32,
        lr: f32,
        wd: f32,
    ) -> Result<f64> {
        let _sp = obs::span_with_arg(obs::Category::Step, "inner_step", t as u64);
        let loss = accumulate_grads_into(
            sess, &self.params, &mut self.shard, batch_seqs,
            &mut self.grads, &mut self.micro_grads, &mut self.tok)?;
        inner.step_in_place(sess, &mut self.params, &mut self.opt_state,
                            &self.grads, t, lr, wd)?;
        Ok(loss)
    }

    /// Per-worker half of the sync boundary: the deltas
    /// theta_global - theta_k for the due tensors, folded through the
    /// error-feedback accumulator when compression is active
    /// (Algorithm 2 lines 13-17).  `compressors` resolves the (possibly
    /// per-tensor, see `--bits-budget`) compressor each tensor goes
    /// through.  Pure per-worker work, safe to run for all workers
    /// concurrently.
    pub fn local_deltas(
        &mut self,
        theta: &Tensors,
        due: &[usize],
        metas: &[SyncTensorMeta],
        apply_ef: bool,
        compressors: &CompressorSet,
    ) -> Vec<Vec<f32>> {
        due.iter()
            .map(|&ti| {
                let mut d = crate::util::sub(&theta[ti], &self.params[ti]);
                if apply_ef {
                    let m = metas[ti];
                    self.ef.compress_with_feedback(ti, &mut d, m.rows, m.cols,
                                                   compressors.get(ti));
                }
                d
            })
            .collect()
    }

    /// L2 norm of this worker's error-feedback residual for tensor
    /// `ti` — the signal the adaptive bit allocator spends budget on.
    pub fn ef_residual_norm(&self, ti: usize) -> f64 {
        self.ef.residual_norm(ti)
    }
}

/// One step's work order for a lane: the worker state (moved in, moved
/// back with the loss) plus the step parameters.
struct StepJob<'c> {
    worker: Worker<'c>,
    sess: &'c Session,
    inner: &'c dyn InnerOptimizer,
    batch_seqs: usize,
    t: f32,
    lr: f32,
    wd: f32,
}

/// A persistent executor thread's endpoints.
struct Lane<'c> {
    tx: mpsc::Sender<StepJob<'c>>,
    rx: mpsc::Receiver<(Worker<'c>, Result<f64>)>,
}

/// Drops the pool's lane senders even if the scoped body panics, so
/// the executor threads always see a closed channel and exit — the
/// enclosing `thread::scope` would otherwise join them forever during
/// unwinding.
struct LaneGuard<'p, 'c>(&'p mut WorkerPool<'c>);

impl Drop for LaneGuard<'_, '_> {
    fn drop(&mut self) {
        self.0.lanes.clear();
    }
}

/// The K inner-optimization trajectories.  The pool owns its inner
/// optimizer: worker state is shaped for it at construction, so a
/// mismatched optimizer/state pair is unrepresentable.  Lanes (the
/// persistent executor threads) exist only inside `scoped`.
pub struct WorkerPool<'c> {
    pub workers: Vec<Worker<'c>>,
    inner: &'c dyn InnerOptimizer,
    lanes: Vec<Lane<'c>>,
}

impl<'c> WorkerPool<'c> {
    /// K replicas of `theta`, each with its own shard `D_k`, zero inner
    /// state and EF accumulator.
    pub fn new(
        sess: &Session,
        corpus: &'c Corpus,
        inner: &'c dyn InnerOptimizer,
        k: usize,
        ef_beta: f32,
        theta: &Tensors,
    ) -> WorkerPool<'c> {
        let n_tensors = sess.manifest.params.len();
        let workers = (0..k)
            .map(|w| {
                Worker::new(
                    theta.clone(),
                    inner.zero_state(sess),
                    corpus.shard(w as u64),
                    ErrorFeedback::new(n_tensors, ef_beta),
                )
            })
            .collect();
        WorkerPool { workers, inner, lanes: Vec::new() }
    }

    pub fn inner(&self) -> &'c dyn InnerOptimizer {
        self.inner
    }

    pub fn k(&self) -> usize {
        self.workers.len()
    }

    /// Run `f` with K persistent executor threads attached (one lane
    /// per worker; the work is PJRT-bound, so K threads is the right
    /// granularity).  Threads live for the whole call and exit when the
    /// lane senders drop; `spawn_executors = false` (the sequential
    /// reference path, or K = 1) runs `f` with no threads at all.
    pub fn scoped<R>(
        &mut self,
        spawn_executors: bool,
        f: impl FnOnce(&mut WorkerPool<'c>) -> R,
    ) -> R {
        let k = self.workers.len();
        if !spawn_executors || k <= 1 {
            return f(self);
        }
        thread::scope(|s| {
            let mut lanes = Vec::with_capacity(k);
            for lane_idx in 0..k {
                let (jtx, jrx) = mpsc::channel::<StepJob<'c>>();
                let (rtx, rrx) = mpsc::channel::<(Worker<'c>, Result<f64>)>();
                s.spawn(move || {
                    // names this lane's row in the trace timeline; the
                    // label is recorded once at spawn (pre-warmup), so
                    // the steady-state step path stays allocation-free
                    if obs::trace::enabled() {
                        obs::trace::label_thread(&format!("lane-{lane_idx}"));
                    }
                    while let Ok(mut job) = jrx.recv() {
                        let loss = job.worker.inner_step(
                            job.sess, job.inner, job.batch_seqs,
                            job.t, job.lr, job.wd);
                        if rtx.send((job.worker, loss)).is_err() {
                            break;
                        }
                    }
                });
                lanes.push(Lane { tx: jtx, rx: rrx });
            }
            self.lanes = lanes;
            // the guard drops the senders (retiring the executors) on
            // both the normal path and unwinding, so the scope's join
            // can always complete
            let mut guard = LaneGuard(self);
            let out = f(&mut *guard.0);
            drop(guard);
            out
        })
    }

    /// One inner step on every *active* worker.  With `parallel` and
    /// attached lanes, each active worker's state ping-pongs through
    /// its persistent executor (channel-based barrier); otherwise the
    /// loops run inline — the sequential reference path.  Either way
    /// losses are reduced in worker-index order over the active set, so
    /// the mean is bit-identical across modes.
    ///
    /// `active` is the fault mask (`FaultPlan::mask`): `None` — the
    /// zero-fault fast path — steps everyone and divides by K, exactly
    /// the pre-elastic arithmetic.  A masked-out worker takes no step,
    /// consumes no data, and is excluded from the loss mean.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &mut self,
        sess: &'c Session,
        batch_seqs: usize,
        t: f32,
        lr: f32,
        wd: f32,
        parallel: bool,
        active: Option<&[bool]>,
    ) -> Result<f64> {
        let k = self.workers.len();
        if let Some(m) = active {
            debug_assert_eq!(m.len(), k, "fault mask must cover every worker");
        }
        let is_active = |i: usize| active.map(|m| m[i]).unwrap_or(true);
        let n_active = (0..k).filter(|&i| is_active(i)).count();
        if n_active == 0 {
            // unreachable through FaultPlan (quorum of one), but direct
            // API misuse must not divide by zero
            anyhow::bail!("worker pool stepped with no active workers");
        }
        if parallel && k > 1 && !self.lanes.is_empty() {
            let inner = self.inner;
            let workers = std::mem::take(&mut self.workers);
            let mut parked: Vec<Option<Worker<'c>>> =
                workers.into_iter().map(Some).collect();
            for (i, lane) in self.lanes.iter().enumerate() {
                if is_active(i) {
                    let worker = parked[i].take().expect("worker parked twice");
                    lane.tx
                        .send(StepJob { worker, sess, inner, batch_seqs, t, lr, wd })
                        .expect("executor lane disappeared");
                }
            }
            // the barrier: collect every active lane in worker-index
            // order; inactive workers never left the main thread.  All
            // worker state is reassembled before any loss error
            // propagates, so the pool stays intact on the abort path
            let mut losses = Vec::with_capacity(n_active);
            for i in 0..k {
                if is_active(i) {
                    let (worker, loss) =
                        self.lanes[i].rx.recv().expect("executor lane disappeared");
                    parked[i] = Some(worker);
                    losses.push(loss);
                }
            }
            self.workers = parked
                .into_iter()
                .map(|w| w.expect("worker lost at the step barrier"))
                .collect();
            let mut mean = 0.0;
            for loss in losses {
                mean += loss? / n_active as f64;
            }
            Ok(mean)
        } else {
            let inner = self.inner;
            let mut mean = 0.0;
            for (i, w) in self.workers.iter_mut().enumerate() {
                if is_active(i) {
                    mean +=
                        w.inner_step(sess, inner, batch_seqs, t, lr, wd)? / n_active as f64;
                }
            }
            Ok(mean)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_selects_the_configured_inner_optimizer() {
        use crate::runtime::NS_STEPS;
        assert_eq!(inner_with(Method::DpAdamw, NS_STEPS, 1).name(), "adamw");
        assert_eq!(inner_with(Method::Diloco, NS_STEPS, 1).name(), "adamw");
        assert_eq!(inner_with(Method::DpMuon, NS_STEPS, 1).name(), "muon");
        assert_eq!(inner_with(Method::Muloco, 0, 2).name(), "muon");
    }

    #[test]
    fn block_periodic_schedule_closed_form() {
        // r = 1: every step orthogonalizes at full depth — the exact
        // classic-Muon dispatch, regardless of step index
        let classic = MuonInner { ns_iters: 5, ortho_interval: 1 };
        for t in 1..=20 {
            assert_eq!(classic.ns_at(t as f32), 5);
        }
        // r = 3: steps 1, 4, 7, ... orthogonalize; the rest run
        // normalized momentum SGD (ns = 0)
        let bp = MuonInner { ns_iters: 5, ortho_interval: 3 };
        for t in 1u64..=12 {
            let want = if (t - 1) % 3 == 0 { 5 } else { 0 };
            assert_eq!(bp.ns_at(t as f32), want, "t={t}");
        }
    }
}
