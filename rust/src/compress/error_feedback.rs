//! Error feedback (Algorithm 2, lines 13-17; Karimireddy et al. 2019).
//!
//! Per-worker, per-tensor residual accumulators:
//!     E <- beta*E + Delta
//!     send C(E)
//!     E <- E - C(E)
//! so only what was *not* communicated persists.  One `ErrorFeedback`
//! instance per worker; the coordinator routes tensor index -> slot.

use super::Compressor;

#[derive(Clone, Debug)]
pub struct ErrorFeedback {
    /// residual decay beta (Algorithm 2; 1.0 = classic EF)
    pub beta: f32,
    /// residual accumulators, one per tensor slot (lazy-initialized)
    residuals: Vec<Option<Vec<f32>>>,
}

impl ErrorFeedback {
    pub fn new(n_tensors: usize, beta: f32) -> ErrorFeedback {
        ErrorFeedback { beta, residuals: vec![None; n_tensors] }
    }

    /// Fold `delta` through the EF accumulator and compressor.
    /// On return `delta` holds the compressed (to-be-communicated)
    /// value C(E); the residual keeps E - C(E).  Returns wire bytes.
    pub fn compress_with_feedback(
        &mut self,
        slot: usize,
        delta: &mut [f32],
        rows: usize,
        cols: usize,
        compressor: &dyn Compressor,
    ) -> usize {
        let res = self.residuals[slot]
            .get_or_insert_with(|| vec![0.0; delta.len()]);
        assert_eq!(res.len(), delta.len(), "tensor slot shape changed");
        // E <- beta*E + Delta  (computed into delta's buffer)
        for (d, e) in delta.iter_mut().zip(res.iter_mut()) {
            *e = self.beta * *e + *d;
            *d = *e;
        }
        let bytes = compressor.compress(delta, rows, cols);
        // E <- E - C(E)
        for (d, e) in delta.iter().zip(res.iter_mut()) {
            *e -= *d;
        }
        bytes
    }

    /// Read-only view of the residual accumulators (checkpointing):
    /// `None` marks a slot that has never been compressed.
    pub fn residuals(&self) -> &[Option<Vec<f32>>] {
        &self.residuals
    }

    /// Rebuild an accumulator set from a snapshot captured via
    /// [`residuals`](ErrorFeedback::residuals) — the resume half of the
    /// checkpoint contract (uncommunicated mass must survive a restart
    /// or Algorithm 2's convergence guarantee silently degrades).
    pub fn restore(beta: f32, residuals: Vec<Option<Vec<f32>>>) -> ErrorFeedback {
        ErrorFeedback { beta, residuals }
    }

    /// L2 norm of a slot's residual (diagnostics / tests).
    pub fn residual_norm(&self, slot: usize) -> f64 {
        match &self.residuals[slot] {
            Some(r) => crate::util::norm(r),
            None => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{NoCompression, TopK};
    use crate::util::rng::Rng;

    #[test]
    fn lossless_compressor_leaves_no_residual() {
        let mut ef = ErrorFeedback::new(1, 1.0);
        let mut x = vec![1.0f32, -2.0, 3.0];
        ef.compress_with_feedback(0, &mut x, 1, 3, &NoCompression);
        assert_eq!(x, vec![1.0, -2.0, 3.0]);
        assert_eq!(ef.residual_norm(0), 0.0);
    }

    #[test]
    fn residual_carries_dropped_mass() {
        let mut ef = ErrorFeedback::new(1, 1.0);
        let mut x = vec![10.0f32, 0.1, 0.2, 0.3];
        ef.compress_with_feedback(0, &mut x, 1, 4, &TopK::new(0.25));
        // only the 10.0 survives; the small entries persist in E
        assert_eq!(x, vec![10.0, 0.0, 0.0, 0.0]);
        let expected = (0.1f64 * 0.1 + 0.2 * 0.2 + 0.3 * 0.3).sqrt();
        assert!((ef.residual_norm(0) - expected).abs() < 1e-6);
    }

    #[test]
    fn dropped_mass_is_eventually_sent() {
        // a constant small signal below the top-k threshold accumulates
        // until EF pushes it through
        let mut ef = ErrorFeedback::new(1, 1.0);
        let mut total_sent = vec![0.0f64; 4];
        for _ in 0..60 {
            // sub-threshold signals at distinct rates; top-1 normally
            // only ever sends the 1.0
            let mut x = vec![1.0f32, 0.30, 0.35, 0.40];
            ef.compress_with_feedback(0, &mut x, 1, 4, &TopK::new(0.25));
            for (t, v) in total_sent.iter_mut().zip(&x) {
                *t += *v as f64;
            }
        }
        // without EF the small coordinates would send exactly 0; with
        // EF their accumulated residuals get through periodically
        for &sent in &total_sent[1..] {
            assert!(sent > 1.0, "{total_sent:?}");
        }
    }

    #[test]
    fn beta_decays_residual() {
        let mut ef = ErrorFeedback::new(1, 0.5);
        // feed a one-off spike that never gets sent (keep=1 takes x[0])
        let mut x = vec![100.0f32, 1.0];
        ef.compress_with_feedback(0, &mut x, 1, 2, &TopK::new(0.5));
        let r1 = ef.residual_norm(0);
        for _ in 0..5 {
            let mut x = vec![100.0f32, 0.0];
            ef.compress_with_feedback(0, &mut x, 1, 2, &TopK::new(0.5));
        }
        // the 1.0 residual decays by beta each round until sent or tiny
        assert!(ef.residual_norm(0) < r1);
    }

    #[test]
    #[should_panic]
    fn shape_change_is_rejected() {
        let mut ef = ErrorFeedback::new(1, 1.0);
        let mut a = vec![1.0f32; 4];
        ef.compress_with_feedback(0, &mut a, 1, 4, &NoCompression);
        let mut b = vec![1.0f32; 5];
        ef.compress_with_feedback(0, &mut b, 1, 5, &NoCompression);
    }
}
