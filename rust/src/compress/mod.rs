//! Pseudogradient compression: quantization, top-k, error feedback.
//!
//! Implements the compressors of the paper's §2/§6.3: linear and
//! statistical quantization (global and row-wise) at 2/4/8 bits, and
//! top-k magnitude sparsification, plus the error-feedback accumulator
//! (Karimireddy et al. 2019) of Algorithm 2.
//!
//! Compressors work on the *decompressed value* semantics the simulated
//! collectives need (quantize-then-dequantize in place) and separately
//! report the exact wire size a real implementation would move, so the
//! netsim layer can charge honest byte counts (including top-k's index
//! overhead, which the paper calls out).

pub mod error_feedback;
pub mod quantize;
pub mod topk;

pub use error_feedback::ErrorFeedback;
pub use quantize::{QuantMode, Quantizer};
pub use topk::TopK;

use crate::comm::wire::{
    DenseBf16, DenseF32, PackedQuant, SparseTopK, WireCodec, WireFormat,
};
use std::sync::Arc;

/// A lossy map applied to one tensor before communication.
pub trait Compressor {
    /// Replace `x` with its quantize/dequantize (or sparsify) image.
    /// `rows`/`cols` give the tensor's 2-D view (rows=1 for vectors).
    /// Returns the wire bytes a real send of the compressed form costs.
    fn compress(&self, x: &mut [f32], rows: usize, cols: usize) -> usize;

    /// Wire bytes for a tensor of `n` elements without running the
    /// compressor (for analytic bandwidth models; the codec's measured
    /// `encode(..).len()` matches this up to per-group bit padding).
    fn wire_bytes(&self, n: usize, rows: usize) -> usize;

    /// The packed wire format this compressor's payloads travel in.
    /// `decode(encode(x))` is bit-identical to `compress(x)`'s output
    /// on the f32 wire (see `comm::wire`), so the collectives can move
    /// real bytes without changing value semantics.
    fn codec(&self, wire: WireFormat) -> Box<dyn WireCodec + Send + Sync>;

    fn name(&self) -> String;
}

/// The identity compressor (FP32 baseline).
#[derive(Clone, Copy, Debug, Default)]
pub struct NoCompression;

impl Compressor for NoCompression {
    fn compress(&self, _x: &mut [f32], _rows: usize, _cols: usize) -> usize {
        self.wire_bytes(_x.len(), _rows)
    }

    fn wire_bytes(&self, n: usize, _rows: usize) -> usize {
        4 * n
    }

    fn codec(&self, wire: WireFormat) -> Box<dyn WireCodec + Send + Sync> {
        match wire {
            WireFormat::F32 => Box::new(DenseF32),
            WireFormat::Bf16 => Box::new(DenseBf16),
        }
    }

    fn name(&self) -> String {
        "fp32".into()
    }
}

/// Per-tensor compressor assignment for one sync round.  The uniform
/// case wraps the run's single compressor; the adaptive-bit-allocation
/// path (`--bits-budget`) swaps in a per-tensor [`Quantizer`] chosen
/// from the EF-residual norms (see `coordinator::sync::allocate_bits`).
#[derive(Clone)]
pub struct CompressorSet {
    base: Arc<dyn Compressor + Send + Sync>,
    per_tensor: Vec<Option<Arc<dyn Compressor + Send + Sync>>>,
}

impl CompressorSet {
    pub fn uniform(base: Arc<dyn Compressor + Send + Sync>) -> CompressorSet {
        CompressorSet { base, per_tensor: Vec::new() }
    }

    /// Override tensor `ti`'s compressor for this round.
    pub fn set(&mut self, ti: usize, c: Arc<dyn Compressor + Send + Sync>) {
        if self.per_tensor.len() <= ti {
            self.per_tensor.resize(ti + 1, None);
        }
        self.per_tensor[ti] = Some(c);
    }

    /// The compressor tensor `ti` goes through.
    pub fn get(&self, ti: usize) -> &(dyn Compressor + Send + Sync) {
        match self.per_tensor.get(ti) {
            Some(Some(c)) => c.as_ref(),
            _ => self.base.as_ref(),
        }
    }

    /// Shared handle to tensor `ti`'s compressor.
    pub fn get_arc(&self, ti: usize) -> Arc<dyn Compressor + Send + Sync> {
        match self.per_tensor.get(ti) {
            Some(Some(c)) => Arc::clone(c),
            _ => Arc::clone(&self.base),
        }
    }
}

/// Configuration enum used by the coordinator / CLI.
#[derive(Clone, Debug, PartialEq)]
pub enum Compression {
    None,
    Quant { bits: u32, mode: QuantMode, rowwise: bool },
    TopK { frac: f64 },
}

impl Compression {
    pub fn build(&self) -> Box<dyn Compressor + Send + Sync> {
        match self {
            Compression::None => Box::new(NoCompression),
            Compression::Quant { bits, mode, rowwise } => {
                Box::new(Quantizer::new(*bits, *mode, *rowwise))
            }
            Compression::TopK { frac } => Box::new(TopK::new(*frac)),
        }
    }

    /// Canonical label: the exact string `parse` round-trips.  Used by
    /// the knob registry for cache keys, spec files and table rows.
    pub fn label(&self) -> String {
        match self {
            Compression::None => "none".to_string(),
            Compression::Quant { bits, mode, rowwise } => format!(
                "q{bits}-{}{}",
                match mode {
                    QuantMode::Linear => "linear",
                    QuantMode::Statistical => "stat",
                },
                if *rowwise { "-rw" } else { "" }
            ),
            Compression::TopK { frac } => format!("topk{frac}"),
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Compression> {
        // forms: none | q<bits>-<linear|stat>[-rw] | topk<frac>
        let s = s.trim();
        if s == "none" || s == "fp32" {
            return Ok(Compression::None);
        }
        if let Some(rest) = s.strip_prefix("topk") {
            return Ok(Compression::TopK { frac: rest.parse()? });
        }
        if let Some(rest) = s.strip_prefix('q') {
            let parts: Vec<&str> = rest.split('-').collect();
            let bits: u32 = parts[0].parse()?;
            let mode = match parts.get(1).copied().unwrap_or("linear") {
                "linear" => QuantMode::Linear,
                "stat" | "statistical" => QuantMode::Statistical,
                other => anyhow::bail!("unknown quant mode {other:?}"),
            };
            let rowwise = parts.get(2) == Some(&"rw");
            return Ok(Compression::Quant { bits, mode, rowwise });
        }
        anyhow::bail!("cannot parse compression spec {s:?}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        assert_eq!(Compression::parse("none").unwrap(), Compression::None);
        assert_eq!(
            Compression::parse("q4-stat-rw").unwrap(),
            Compression::Quant { bits: 4, mode: QuantMode::Statistical, rowwise: true }
        );
        assert_eq!(
            Compression::parse("topk0.05").unwrap(),
            Compression::TopK { frac: 0.05 }
        );
        assert!(Compression::parse("zstd").is_err());
    }

    #[test]
    fn label_round_trips_through_parse() {
        for spec in ["none", "q8-linear", "q4-stat", "q2-linear-rw", "topk0.05"] {
            let c = Compression::parse(spec).unwrap();
            assert_eq!(Compression::parse(&c.label()).unwrap(), c);
            assert_eq!(c.label(), spec, "label must be canonical");
        }
        // long-form mode names normalize to the canonical short form
        assert_eq!(Compression::parse("q4-statistical").unwrap().label(),
                   "q4-stat");
    }

    #[test]
    fn identity_compressor_is_lossless() {
        let mut x = vec![1.0f32, -2.5, 3.25];
        let orig = x.clone();
        let bytes = NoCompression.compress(&mut x, 1, 3);
        assert_eq!(x, orig);
        assert_eq!(bytes, 12);
    }
}
