//! Pseudogradient compression: quantization, top-k, error feedback.
//!
//! Implements the compressors of the paper's §2/§6.3: linear and
//! statistical quantization (global and row-wise) at 2/4/8 bits, and
//! top-k magnitude sparsification, plus the error-feedback accumulator
//! (Karimireddy et al. 2019) of Algorithm 2.
//!
//! Compressors work on the *decompressed value* semantics the simulated
//! collectives need (quantize-then-dequantize in place) and separately
//! report the exact wire size a real implementation would move, so the
//! netsim layer can charge honest byte counts (including top-k's index
//! overhead, which the paper calls out).

pub mod error_feedback;
pub mod quantize;
pub mod topk;

pub use error_feedback::ErrorFeedback;
pub use quantize::{QuantMode, Quantizer};
pub use topk::TopK;

/// A lossy map applied to one tensor before communication.
pub trait Compressor {
    /// Replace `x` with its quantize/dequantize (or sparsify) image.
    /// `rows`/`cols` give the tensor's 2-D view (rows=1 for vectors).
    /// Returns the wire bytes a real send of the compressed form costs.
    fn compress(&self, x: &mut [f32], rows: usize, cols: usize) -> usize;

    /// Wire bytes for a tensor of `n` elements without running the
    /// compressor (for analytic bandwidth models).
    fn wire_bytes(&self, n: usize, rows: usize) -> usize;

    fn name(&self) -> String;
}

/// The identity compressor (FP32 baseline).
#[derive(Clone, Copy, Debug, Default)]
pub struct NoCompression;

impl Compressor for NoCompression {
    fn compress(&self, _x: &mut [f32], _rows: usize, _cols: usize) -> usize {
        self.wire_bytes(_x.len(), _rows)
    }

    fn wire_bytes(&self, n: usize, _rows: usize) -> usize {
        4 * n
    }

    fn name(&self) -> String {
        "fp32".into()
    }
}

/// Configuration enum used by the coordinator / CLI.
#[derive(Clone, Debug, PartialEq)]
pub enum Compression {
    None,
    Quant { bits: u32, mode: QuantMode, rowwise: bool },
    TopK { frac: f64 },
}

impl Compression {
    pub fn build(&self) -> Box<dyn Compressor + Send + Sync> {
        match self {
            Compression::None => Box::new(NoCompression),
            Compression::Quant { bits, mode, rowwise } => {
                Box::new(Quantizer::new(*bits, *mode, *rowwise))
            }
            Compression::TopK { frac } => Box::new(TopK::new(*frac)),
        }
    }

    /// Canonical label: the exact string `parse` round-trips.  Used by
    /// the knob registry for cache keys, spec files and table rows.
    pub fn label(&self) -> String {
        match self {
            Compression::None => "none".to_string(),
            Compression::Quant { bits, mode, rowwise } => format!(
                "q{bits}-{}{}",
                match mode {
                    QuantMode::Linear => "linear",
                    QuantMode::Statistical => "stat",
                },
                if *rowwise { "-rw" } else { "" }
            ),
            Compression::TopK { frac } => format!("topk{frac}"),
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Compression> {
        // forms: none | q<bits>-<linear|stat>[-rw] | topk<frac>
        let s = s.trim();
        if s == "none" || s == "fp32" {
            return Ok(Compression::None);
        }
        if let Some(rest) = s.strip_prefix("topk") {
            return Ok(Compression::TopK { frac: rest.parse()? });
        }
        if let Some(rest) = s.strip_prefix('q') {
            let parts: Vec<&str> = rest.split('-').collect();
            let bits: u32 = parts[0].parse()?;
            let mode = match parts.get(1).copied().unwrap_or("linear") {
                "linear" => QuantMode::Linear,
                "stat" | "statistical" => QuantMode::Statistical,
                other => anyhow::bail!("unknown quant mode {other:?}"),
            };
            let rowwise = parts.get(2) == Some(&"rw");
            return Ok(Compression::Quant { bits, mode, rowwise });
        }
        anyhow::bail!("cannot parse compression spec {s:?}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        assert_eq!(Compression::parse("none").unwrap(), Compression::None);
        assert_eq!(
            Compression::parse("q4-stat-rw").unwrap(),
            Compression::Quant { bits: 4, mode: QuantMode::Statistical, rowwise: true }
        );
        assert_eq!(
            Compression::parse("topk0.05").unwrap(),
            Compression::TopK { frac: 0.05 }
        );
        assert!(Compression::parse("zstd").is_err());
    }

    #[test]
    fn label_round_trips_through_parse() {
        for spec in ["none", "q8-linear", "q4-stat", "q2-linear-rw", "topk0.05"] {
            let c = Compression::parse(spec).unwrap();
            assert_eq!(Compression::parse(&c.label()).unwrap(), c);
            assert_eq!(c.label(), spec, "label must be canonical");
        }
        // long-form mode names normalize to the canonical short form
        assert_eq!(Compression::parse("q4-statistical").unwrap().label(),
                   "q4-stat");
    }

    #[test]
    fn identity_compressor_is_lossless() {
        let mut x = vec![1.0f32, -2.5, 3.25];
        let orig = x.clone();
        let bytes = NoCompression.compress(&mut x, 1, 3);
        assert_eq!(x, orig);
        assert_eq!(bytes, 12);
    }
}
