//! Linear and statistical quantization, global or row-wise (§2, §6.3).
//!
//! * Linear: 2^bits levels uniformly spaced over [min, max] of the
//!   quantization group (whole tensor, or each row).
//! * Statistical: levels placed at the empirical quantiles of the
//!   group, "assigning higher resolution to more frequently occurring
//!   values" — implemented as mid-quantile codebook + nearest-level
//!   encoding via binary search over the sorted codebook.
//!
//! Wire cost: n*bits/8 payload + per-group metadata (min/max for
//! linear; the 2^bits-entry codebook for statistical).  Row-wise
//! quantization pays metadata per row but (as the paper notes) gains
//! parallelism and avoids cross-row statistics — we reproduce its
//! accuracy behaviour here and its bandwidth in netsim.

use super::Compressor;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMode {
    Linear,
    Statistical,
}

#[derive(Clone, Debug)]
pub struct Quantizer {
    pub bits: u32,
    pub mode: QuantMode,
    pub rowwise: bool,
}

impl Quantizer {
    pub fn new(bits: u32, mode: QuantMode, rowwise: bool) -> Quantizer {
        assert!((1..=16).contains(&bits), "bits must be in 1..=16");
        Quantizer { bits, mode, rowwise }
    }

    fn levels(&self) -> usize {
        1usize << self.bits
    }

    fn quantize_group(&self, x: &mut [f32]) {
        if x.is_empty() {
            return;
        }
        match self.mode {
            QuantMode::Linear => self.quantize_linear(x),
            QuantMode::Statistical => self.quantize_statistical(x),
        }
    }

    fn quantize_linear(&self, x: &mut [f32]) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in x.iter() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if !lo.is_finite() || !hi.is_finite() || lo == hi {
            // constant (or degenerate) group: single level reproduces it
            return;
        }
        let levels = self.levels() as f32;
        let scale = (hi - lo) / (levels - 1.0);
        for v in x.iter_mut() {
            let q = ((*v - lo) / scale).round().clamp(0.0, levels - 1.0);
            *v = lo + q * scale;
        }
    }

    fn quantize_statistical(&self, x: &mut [f32]) {
        let levels = self.levels().min(x.len());
        let mut sorted: Vec<f32> = x.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // mid-quantile codebook: level j at quantile (j + 0.5) / levels
        let mut codebook: Vec<f32> = (0..levels)
            .map(|j| {
                let q = (j as f64 + 0.5) / levels as f64;
                sorted[((q * sorted.len() as f64) as usize)
                    .min(sorted.len() - 1)]
            })
            .collect();
        codebook.dedup();
        for v in x.iter_mut() {
            *v = nearest(&codebook, *v);
        }
    }

    fn metadata_bytes_per_group(&self) -> usize {
        match self.mode {
            QuantMode::Linear => 8, // f32 min + f32 max
            QuantMode::Statistical => 4 * self.levels(), // codebook
        }
    }
}

/// Nearest value in a sorted codebook (binary search + neighbour check).
fn nearest(codebook: &[f32], v: f32) -> f32 {
    match codebook.binary_search_by(|c| c.partial_cmp(&v).unwrap()) {
        Ok(i) => codebook[i],
        Err(i) => {
            if i == 0 {
                codebook[0]
            } else if i >= codebook.len() {
                codebook[codebook.len() - 1]
            } else {
                let lo = codebook[i - 1];
                let hi = codebook[i];
                if (v - lo).abs() <= (hi - v).abs() {
                    lo
                } else {
                    hi
                }
            }
        }
    }
}

impl Compressor for Quantizer {
    fn compress(&self, x: &mut [f32], rows: usize, cols: usize) -> usize {
        if self.rowwise && rows > 1 {
            debug_assert_eq!(rows * cols, x.len());
            for r in 0..rows {
                self.quantize_group(&mut x[r * cols..(r + 1) * cols]);
            }
        } else {
            self.quantize_group(x);
        }
        self.wire_bytes(x.len(), rows)
    }

    fn wire_bytes(&self, n: usize, rows: usize) -> usize {
        let groups = if self.rowwise { rows.max(1) } else { 1 };
        n * self.bits as usize / 8 + groups * self.metadata_bytes_per_group()
    }

    fn codec(
        &self,
        _wire: crate::comm::wire::WireFormat,
    ) -> Box<dyn crate::comm::wire::WireCodec + Send + Sync> {
        // codes are already k-bit and metadata stays f32: the packed
        // quant wire is independent of the dense word format
        Box::new(crate::comm::wire::PackedQuant { q: self.clone() })
    }

    fn name(&self) -> String {
        format!(
            "q{}-{}{}",
            self.bits,
            match self.mode {
                QuantMode::Linear => "linear",
                QuantMode::Statistical => "stat",
            },
            if self.rowwise { "-rw" } else { "" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal_f32()).collect()
    }

    #[test]
    fn linear_8bit_is_nearly_lossless() {
        let mut x = gaussian(4096, 0);
        let orig = x.clone();
        Quantizer::new(8, QuantMode::Linear, false).compress(&mut x, 1, 4096);
        let err: f32 = x.iter().zip(&orig).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
        let range = orig.iter().fold(0.0f32, |m, v| m.max(v.abs())) * 2.0;
        assert!(err <= range / 255.0 * 0.51 + 1e-6, "{err}");
    }

    #[test]
    fn values_land_on_grid() {
        let mut x = gaussian(512, 1);
        Quantizer::new(2, QuantMode::Linear, false).compress(&mut x, 1, 512);
        let mut distinct = x.clone();
        distinct.sort_by(|a, b| a.partial_cmp(b).unwrap());
        distinct.dedup();
        assert!(distinct.len() <= 4, "{}", distinct.len());
    }

    #[test]
    fn statistical_beats_linear_at_2bit_on_heavy_tails() {
        // heavy-tailed data is where the paper sees statistical win
        let mut r = Rng::new(2);
        let orig: Vec<f32> = (0..8192)
            .map(|_| {
                let g = r.normal_f32();
                g * g * g // cube for heavy tails
            })
            .collect();
        let mse = |q: &Quantizer| {
            let mut x = orig.clone();
            let n = x.len();
            q.compress(&mut x, 1, n);
            x.iter().zip(&orig).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
        };
        let lin = mse(&Quantizer::new(2, QuantMode::Linear, false));
        let stat = mse(&Quantizer::new(2, QuantMode::Statistical, false));
        assert!(stat < lin, "stat {stat} vs lin {lin}");
    }

    #[test]
    fn rowwise_respects_row_boundaries() {
        // two rows with very different scales: row-wise must adapt
        let rows = 2;
        let cols = 256;
        let mut x: Vec<f32> = Vec::new();
        let mut r = Rng::new(3);
        for _ in 0..cols {
            x.push(r.normal_f32() * 1e-3);
        }
        for _ in 0..cols {
            x.push(r.normal_f32() * 1e3);
        }
        let orig = x.clone();
        Quantizer::new(4, QuantMode::Linear, true).compress(&mut x, rows, cols);
        let err_small: f64 = x[..cols].iter().zip(&orig[..cols])
            .map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let mut xg = orig.clone();
        Quantizer::new(4, QuantMode::Linear, false).compress(&mut xg, rows, cols);
        let err_small_global: f64 = xg[..cols].iter().zip(&orig[..cols])
            .map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        assert!(err_small < err_small_global * 1e-3,
                "{err_small} vs {err_small_global}");
    }

    #[test]
    fn constant_tensor_roundtrips() {
        let mut x = vec![0.75f32; 100];
        Quantizer::new(2, QuantMode::Linear, false).compress(&mut x, 1, 100);
        assert!(x.iter().all(|&v| v == 0.75));
        let mut y = vec![0.75f32; 100];
        Quantizer::new(2, QuantMode::Statistical, false).compress(&mut y, 1, 100);
        assert!(y.iter().all(|&v| v == 0.75));
    }

    #[test]
    fn wire_bytes_accounting() {
        let q = Quantizer::new(4, QuantMode::Linear, false);
        assert_eq!(q.wire_bytes(1000, 10), 500 + 8);
        let qr = Quantizer::new(4, QuantMode::Linear, true);
        assert_eq!(qr.wire_bytes(1000, 10), 500 + 80);
        let qs = Quantizer::new(2, QuantMode::Statistical, false);
        assert_eq!(qs.wire_bytes(1000, 1), 250 + 16);
    }

    #[test]
    fn idempotent() {
        let mut x = gaussian(1024, 4);
        let q = Quantizer::new(4, QuantMode::Linear, false);
        q.compress(&mut x, 1, 1024);
        let once = x.clone();
        q.compress(&mut x, 1, 1024);
        assert_eq!(x, once);
    }
}
