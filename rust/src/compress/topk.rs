//! Top-k magnitude sparsification (§2, §6.3).
//!
//! Keeps the k% largest-magnitude entries; zeros the rest.  The wire
//! format must also carry the sparsity pattern, so the true compression
//! ratio is worse than the sparsity fraction (4 value bytes + 4 index
//! bytes per survivor) — the overhead the paper uses to argue 2-bit
//! quantization beats 5-10% top-k.

use super::Compressor;

#[derive(Clone, Copy, Debug)]
pub struct TopK {
    /// fraction of entries kept, in (0, 1]
    pub frac: f64,
}

impl TopK {
    pub fn new(frac: f64) -> TopK {
        assert!(frac > 0.0 && frac <= 1.0, "frac must be in (0,1]");
        TopK { frac }
    }

    /// Survivor count for an `n`-element tensor (shared with the
    /// `comm::wire::SparseTopK` codec, which derives it from `n`
    /// instead of shipping a count header).
    pub(crate) fn keep_count(&self, n: usize) -> usize {
        ((n as f64 * self.frac).round() as usize).clamp(1, n)
    }
}

impl Compressor for TopK {
    fn compress(&self, x: &mut [f32], _rows: usize, _cols: usize) -> usize {
        let n = x.len();
        let k = self.keep_count(n);
        if k == n {
            return self.wire_bytes(n, 1);
        }
        // threshold via select_nth on |x| (O(n) average)
        let mut mags: Vec<f32> = x.iter().map(|v| v.abs()).collect();
        let idx = n - k;
        mags.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap());
        let thresh = mags[idx];
        // keep strictly-above first, then fill ties deterministically
        let mut kept = 0usize;
        for v in x.iter() {
            if v.abs() > thresh {
                kept += 1;
            }
        }
        let mut ties_left = k.saturating_sub(kept);
        for v in x.iter_mut() {
            let a = v.abs();
            if a > thresh {
                continue;
            }
            if a == thresh && ties_left > 0 {
                ties_left -= 1;
                continue;
            }
            *v = 0.0;
        }
        self.wire_bytes(n, 1)
    }

    fn wire_bytes(&self, n: usize, _rows: usize) -> usize {
        // value + index per kept entry (the paper's sparsity-pattern cost)
        8 * self.keep_count(n)
    }

    fn codec(
        &self,
        wire: crate::comm::wire::WireFormat,
    ) -> Box<dyn crate::comm::wire::WireCodec + Send + Sync> {
        // the survivor-value section narrows with the wire format;
        // indices stay u32
        Box::new(crate::comm::wire::SparseTopK { t: *self, values: wire })
    }

    fn name(&self) -> String {
        format!("topk{}", self.frac)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn keeps_exactly_k() {
        let mut r = Rng::new(0);
        let mut x: Vec<f32> = (0..1000).map(|_| r.normal_f32()).collect();
        TopK::new(0.1).compress(&mut x, 1, 1000);
        let nnz = x.iter().filter(|v| **v != 0.0).count();
        assert_eq!(nnz, 100);
    }

    #[test]
    fn keeps_largest_magnitudes() {
        let mut x = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 1.0];
        TopK::new(0.5).compress(&mut x, 1, 6);
        assert_eq!(x, vec![0.0, -5.0, 0.0, 3.0, 0.0, 1.0]);
    }

    #[test]
    fn full_fraction_is_identity() {
        let mut x = vec![1.0f32, -2.0, 0.0, 3.0];
        let orig = x.clone();
        TopK::new(1.0).compress(&mut x, 1, 4);
        assert_eq!(x, orig);
    }

    #[test]
    fn handles_ties() {
        let mut x = vec![1.0f32; 10];
        TopK::new(0.3).compress(&mut x, 1, 10);
        assert_eq!(x.iter().filter(|v| **v != 0.0).count(), 3);
    }

    #[test]
    fn wire_bytes_include_indices() {
        let t = TopK::new(0.01);
        // 1% of 10_000 = 100 kept * 8 bytes
        assert_eq!(t.wire_bytes(10_000, 1), 800);
    }

    #[test]
    fn tiny_tensor_keeps_at_least_one() {
        let mut x = vec![0.5f32, -0.1];
        TopK::new(0.01).compress(&mut x, 1, 2);
        assert_eq!(x.iter().filter(|v| **v != 0.0).count(), 1);
        assert_eq!(x[0], 0.5);
    }
}
