//! Benchmark harness (criterion is unavailable offline; this is a
//! self-contained timing harness with warmup + repeated trials that
//! `cargo bench` runs).  Two groups:
//!
//! * L3 hot-path microbenches: quantizers, top-k, error feedback,
//!   collectives, outer step, SVD, dot/cosine — the components on the
//!   coordinator's synchronization path.
//! * native GEMM benches: the cache-blocked lane-parallel `sgemm`
//!   against the naive triple-loop reference (the acceptance bar:
//!   >= 3x at d_model >= 256 on a multi-core host).
//! * end-to-end runtime benches (one per paper-table workload):
//!   fwd_grad / apply_muon / apply_adamw per config, plus a full MuLoCo
//!   round — the Table 9 generator's underlying measurements.  These
//!   run on whichever backend `Session::load` selects (native on the
//!   default build; PJRT when artifacts + feature are present).

use std::time::Instant;

use muloco::analysis::svd;
use muloco::analysis::Mat;
use muloco::comm::{AllToAll, CollectiveOp, Hierarchical, OpKind, Ring,
                   Topology};
use muloco::compress::{Compressor, ErrorFeedback, QuantMode, Quantizer, TopK};
use muloco::coordinator::{train, Method, NesterovOuter, RunSpec};
use muloco::runtime::native::gemm::{time_blocked_vs_naive,
                                    time_scalar_vs_active};
use muloco::runtime::native::muon::newton_schulz_group;
use muloco::runtime::Session;
use muloco::util::rng::Rng;

fn bench<F: FnMut()>(name: &str, bytes_per_iter: usize, mut f: F) {
    // warmup
    for _ in 0..3 {
        f();
    }
    let mut iters = 0u64;
    let t0 = Instant::now();
    while t0.elapsed().as_secs_f64() < 0.5 {
        f();
        iters += 1;
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let gbs = bytes_per_iter as f64 / per / 1e9;
    if bytes_per_iter > 0 {
        println!("{name:<44} {:>12.1} us/iter {:>8.2} GB/s", per * 1e6, gbs);
    } else {
        println!("{name:<44} {:>12.1} us/iter", per * 1e6);
    }
}

fn main() -> anyhow::Result<()> {
    println!("== L3 hot-path microbenches ==");
    let mut rng = Rng::new(0);
    let n = 1 << 20; // 1M f32 = one decent tensor shard
    let base: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();

    for (label, q) in [
        ("quantize q8-linear (1M f32)", Quantizer::new(8, QuantMode::Linear, false)),
        ("quantize q4-linear (1M f32)", Quantizer::new(4, QuantMode::Linear, false)),
        ("quantize q4-linear-rowwise (1024x1024)", Quantizer::new(4, QuantMode::Linear, true)),
        ("quantize q4-statistical (1M f32)", Quantizer::new(4, QuantMode::Statistical, false)),
    ] {
        let mut buf = base.clone();
        bench(label, 4 * n, || {
            buf.copy_from_slice(&base);
            q.compress(&mut buf, 1024, 1024);
        });
    }

    {
        let t = TopK::new(0.01);
        let mut buf = base.clone();
        bench("top-k 1% (1M f32)", 4 * n, || {
            buf.copy_from_slice(&base);
            t.compress(&mut buf, 1, n);
        });
    }

    {
        let q = Quantizer::new(4, QuantMode::Linear, false);
        let mut ef = ErrorFeedback::new(1, 0.9);
        let mut buf = base.clone();
        bench("error feedback + q4 (1M f32)", 4 * n, || {
            buf.copy_from_slice(&base);
            ef.compress_with_feedback(0, &mut buf, 1, n, &q);
        });
    }

    {
        let k = 8;
        let shard = n / 8;
        let bufs: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..shard).map(|_| rng.normal_f32()).collect())
            .collect();
        let q = Quantizer::new(4, QuantMode::Linear, false);
        let mut work = bufs.clone();
        let dense = CollectiveOp::dense();
        bench("ring all-reduce K=8 (128K f32 each)", 4 * n, || {
            work.clone_from(&bufs);
            Ring.reduce_mean(&mut work, &dense, 1, shard);
        });
        let quant = CollectiveOp::new(&q, OpKind::TwoQuant);
        bench("quantized reduce (a2a+ag) K=8 q4", 4 * n, || {
            work.clone_from(&bufs);
            AllToAll.reduce_mean(&mut work, &quant, 1, shard);
        });
        let hier = Hierarchical::new(2);
        bench("hierarchical 2-DC reduce K=8 q4", 4 * n, || {
            work.clone_from(&bufs);
            hier.reduce_mean(&mut work, &quant, 1, shard);
        });
        let t = TopK::new(0.05);
        let sparse =
            CollectiveOp::new(&t, OpKind::SparseGather { presparsified: false });
        bench("sparse all-gather K=8 top-5%", 4 * n, || {
            work.clone_from(&bufs);
            Ring.reduce_mean(&mut work, &sparse, 1, shard);
        });
    }

    {
        let mut outer = NesterovOuter::new(0.7, 0.9, &[n]);
        let mut theta = base.clone();
        let psi: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 1e-3).collect();
        bench("outer Nesterov step (1M f32)", 12 * n, || {
            outer.step_tensor(0, &mut theta, &psi);
        });
    }

    {
        let m = Mat {
            rows: 64,
            cols: 64,
            data: (0..64 * 64).map(|_| rng.normal()).collect(),
        };
        bench("one-sided Jacobi SVD 64x64", 0, || {
            let _ = svd(&m);
        });
    }

    {
        let a = base.clone();
        let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        bench("dot product (1M f32)", 8 * n, || {
            std::hint::black_box(muloco::util::dot(&a, &b));
        });
        let mut y = a.clone();
        bench("add_assign (1M f32)", 8 * n, || {
            muloco::util::add_assign(&mut y, &b);
        });
        bench("scale (1M f32)", 4 * n, || {
            muloco::util::scale(&mut y, 1.000001);
        });
        bench("sub / delta (1M f32)", 12 * n, || {
            std::hint::black_box(muloco::util::sub(&a, &b));
        });
    }

    // === native GEMM: blocked lane-parallel vs naive reference =======
    // (same measurement as `muloco bench` / BENCH_native.json —
    //  gemm::time_blocked_vs_naive is the single definition)
    println!("\n== native GEMM (blocked vs naive triple-loop) ==");
    for d in [64usize, 128, 256, 512] {
        let (blocked, naive) = time_blocked_vs_naive(d, if d >= 512 { 3 } else { 5 });
        let gflops = 2.0 * (d * d * d) as f64 / blocked / 1e9;
        println!(
            "sgemm {d:>3}^3: blocked {:>9.1} us ({gflops:>6.2} GFLOP/s)  \
             naive {:>10.1} us  speedup {:>5.1}x",
            blocked * 1e6,
            naive * 1e6,
            naive / blocked
        );
    }
    // single-lane scalar reference vs the dispatched microkernel (the
    // SIMD body under `--features simd`, the identical scalar body on
    // the default build — speedup 1.0x there, by construction)
    println!("\n== native GEMM (microkernel vs scalar reference, simd={}) ==",
             cfg!(feature = "simd"));
    for d in [64usize, 128, 256] {
        let (scalar, active) = time_scalar_vs_active(d, 5);
        let gflops = 2.0 * (d * d * d) as f64 / active / 1e9;
        println!(
            "sgemm {d:>3}^3: active {:>9.1} us ({gflops:>6.2} GFLOP/s)  \
             scalar {:>9.1} us  speedup {:>5.2}x",
            active * 1e6,
            scalar * 1e6,
            scalar / active
        );
    }
    {
        // batched Newton-Schulz over an 8-matrix 128x128 group (the
        // Muon orthogonalization hot-spot at `med` scale)
        let (r, cdim, nb) = (128usize, 128usize, 8usize);
        let base: Vec<Vec<f32>> = (0..nb)
            .map(|_| (0..r * cdim).map(|_| rng.normal_f32()).collect())
            .collect();
        let mut work = base.clone();
        bench("newton-schulz5 batched 8x128x128", 0, || {
            work.clone_from(&base);
            newton_schulz_group(&mut work, r, cdim, 5);
        });
    }

    // === end-to-end runtime benches (paper Table 9 measurements) =====
    let dir = std::path::PathBuf::from("artifacts/nano");
    let sess = Session::load(&dir)?;
    println!("\n== end-to-end runtime benches (nano, {}) ==", sess.platform());
    let cfg_m = &sess.manifest.config;
    let params = sess.init_params(0)?;
    let tokens: Vec<i32> = (0..cfg_m.microbatch * cfg_m.seq_len)
        .map(|i| (i * 31 % cfg_m.vocab) as i32)
        .collect();
    let (_, grads) = sess.fwd_grad(&params, &tokens)?;
    bench("fwd_grad (microbatch 4x64)", 0, || {
        let _ = sess.fwd_grad(&params, &tokens).unwrap();
    });
    let mu_state = sess.zero_muon_state();
    bench("apply_muon (41.8K params)", 0, || {
        let _ = sess.apply_muon(&params, &mu_state, &grads, 1.0, 0.05, 0.0)
            .unwrap();
    });
    let aw_state = sess.zero_adamw_state();
    bench("apply_adamw (41.8K params)", 0, || {
        let _ = sess.apply_adamw(&params, &aw_state, &grads, 1.0, 0.05, 0.0)
            .unwrap();
    });
    bench("eval_step (microbatch 4x64)", 0, || {
        let _ = sess.eval_step(&params, &tokens).unwrap();
    });

    // one full outer round per method — the Table 9 end-to-end row
    println!("\n== full training rounds (K=4, H=5, B=16) ==");
    for method in [Method::Diloco, Method::Muloco] {
        let cfg = RunSpec::new("nano", method)
            .batch(16)
            .workers(4)
            .steps(5)
            .sync_interval(5)
            .eval_every(5)
            .eval_batches(1)
            .build()?;
        let t0 = Instant::now();
        let r = train(&sess, &cfg)?;
        let per_step = t0.elapsed().as_secs_f64() / 5.0;
        println!(
            "{:<10} {:>10.1} ms/global-step  ({:.0} tokens/s, {} B comm/worker)",
            method.name(), per_step * 1e3,
            (cfg.global_batch * 64) as f64 / per_step,
            r.comm.bytes_per_worker
        );
    }

    // worker-pool scaling: the acceptance check for the parallel
    // engine — the inner-step phase of a K-worker run must land well
    // under K x the single-worker wall clock on a multi-core host
    println!("\n== worker-pool scaling (MuLoCo, H=5, B=32) ==");
    let round = |k: usize, parallel: bool| -> anyhow::Result<f64> {
        let cfg = RunSpec::new("nano", Method::Muloco)
            .batch(32)
            .workers(k)
            .steps(10)
            .sync_interval(5)
            .eval_every(10)
            .eval_batches(1)
            .parallel(parallel)
            .build()?;
        let t0 = Instant::now();
        let _ = train(&sess, &cfg)?;
        Ok(t0.elapsed().as_secs_f64())
    };
    let k = 8;
    let t_seq = round(k, false)?;
    let t_par = round(k, true)?;
    println!("K={k} sequential  {:>8.1} ms/global-step", t_seq * 1e2);
    println!("K={k} parallel    {:>8.1} ms/global-step  ({:.2}x speedup)",
             t_par * 1e2, t_seq / t_par);
    Ok(())
}
