//! The fault-tolerance subsystem's contract, end to end:
//!
//! * **bit-for-bit resume** — killing a run at any sync boundary
//!   (`--halt-after`, the deterministic crash stand-in) and resuming
//!   from the durable checkpoint reproduces the uninterrupted run's
//!   curves, comm accounting, fault ledger, token count and final
//!   parameters exactly — sequential and parallel, blocking and
//!   overlapped (`tau > 0`, with boundaries in flight at the save);
//! * **corruption safety** — truncated pages, flipped bits, format
//!   version drift and math-knob drift all fail with actionable
//!   errors, never garbage state;
//! * **elastic determinism** — a seeded `FaultPlan` dropout run is
//!   identical across repeats and across parallel/sequential modes,
//!   its accounting matches the pure schedule, and the pseudogradient
//!   mean renormalizes over the surviving participants.

use std::fs;
use std::path::PathBuf;

use muloco::ckpt;
use muloco::compress::{Compression, ErrorFeedback};
use muloco::collectives::CommStats;
use muloco::coordinator::{train, FaultPlan, Method, NesterovOuter, RunResult,
                          RunSpec, SyncEngine, SyncPlan, SyncTensorMeta,
                          Worker};
use muloco::data::Corpus;
use muloco::runtime::Session;

fn sess() -> Session {
    Session::load(std::path::Path::new("artifacts/nano")).expect("session")
}

/// A 12-step K=4 nano run with boundaries at 4, 8, 12.
fn base(tau: u64, parallel: bool) -> RunSpec {
    RunSpec::new("nano", Method::Muloco)
        .batch(16)
        .workers(4)
        .steps(12)
        .sync_interval(4)
        .eval_every(4)
        .eval_batches(2)
        .warmup(2)
        .tau(tau)
        .parallel(parallel)
}

fn tmp(tag: &str) -> PathBuf {
    let d = PathBuf::from("target")
        .join(format!("ckpt-resume-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    d
}

fn assert_same(a: &RunResult, b: &RunResult, tag: &str) {
    assert_eq!(a.eval_curve, b.eval_curve, "eval curve diverged: {tag}");
    assert_eq!(a.train_curve, b.train_curve, "train curve diverged: {tag}");
    assert_eq!(a.acc_curve, b.acc_curve, "acc curve diverged: {tag}");
    assert_eq!(a.comm, b.comm, "comm accounting diverged: {tag}");
    assert_eq!(a.faults, b.faults, "fault ledger diverged: {tag}");
    assert_eq!(a.tokens, b.tokens, "token count diverged: {tag}");
    assert_eq!(a.smoothed_final.to_bits(), b.smoothed_final.to_bits(),
               "smoothed final diverged: {tag}");
    assert_eq!(a.final_params, b.final_params, "final params diverged: {tag}");
}

/// The signature guarantee: kill at EVERY sync boundary, resume, and
/// compare against the uninterrupted run — for the sequential reference
/// path, the parallel engine, and overlapped sync with a boundary
/// mid-flight at the save point.
#[test]
fn resume_at_every_sync_boundary_is_bit_for_bit() {
    let sess = sess();
    for parallel in [false, true] {
        for tau in [0u64, 2] {
            let full =
                train(&sess, &base(tau, parallel).build().unwrap()).unwrap();
            for halt in [4u64, 8] {
                let tag = format!("parallel={parallel} tau={tau} halt={halt}");
                let dir = tmp(&format!("b-{parallel}-{tau}-{halt}"));
                let dir_s = dir.to_string_lossy().to_string();
                // the "crash": checkpoint at each boundary, die at `halt`
                let halted = base(tau, parallel)
                    .save_every(4)
                    .ckpt_dir(dir_s.clone())
                    .halt_after(halt)
                    .build()
                    .unwrap();
                let partial = train(&sess, &halted).unwrap();
                assert!(partial.train_curve.len() < full.train_curve.len(),
                        "halted run must be truncated: {tag}");
                // resurrection: resume from the newest checkpoint
                let resumed_cfg =
                    base(tau, parallel).resume(dir_s).build().unwrap();
                let resumed = train(&sess, &resumed_cfg).unwrap();
                assert_same(&full, &resumed, &tag);
                fs::remove_dir_all(&dir).unwrap();
            }
        }
    }
}

/// Compression + error feedback: the EF residuals are part of the
/// contract — losing them on resume would silently change what gets
/// communicated at later boundaries.
#[test]
fn resume_preserves_error_feedback_residuals() {
    let sess = sess();
    let spec = || {
        base(0, true)
            .compression(Compression::parse("topk0.25").unwrap())
            .error_feedback(true)
    };
    let full = train(&sess, &spec().build().unwrap()).unwrap();
    let dir = tmp("ef");
    let dir_s = dir.to_string_lossy().to_string();
    let halted = spec()
        .save_every(4)
        .ckpt_dir(dir_s.clone())
        .halt_after(4)
        .build()
        .unwrap();
    train(&sess, &halted).unwrap();
    let resumed = train(&sess, &spec().resume(dir_s).build().unwrap()).unwrap();
    assert_same(&full, &resumed, "topk+ef");
    fs::remove_dir_all(&dir).unwrap();
}

/// Corruption paths through the public resume entry point: every
/// failure names its cause; none of them touch training state.
#[test]
fn resume_rejects_drift_and_corruption_with_actionable_errors() {
    let sess = sess();
    let dir = tmp("drift");
    let dir_s = dir.to_string_lossy().to_string();
    let halted = base(0, true)
        .save_every(4)
        .ckpt_dir(dir_s.clone())
        .halt_after(4)
        .build()
        .unwrap();
    train(&sess, &halted).unwrap();

    // knob-map drift: same model, different inner LR
    let drifted = base(0, true)
        .lr(0.123)
        .resume(dir_s.clone())
        .build()
        .unwrap();
    let err = format!("{:#}", train(&sess, &drifted).unwrap_err());
    assert!(err.contains("different math knobs"), "{err}");

    // format-version drift
    let step_dir = ckpt::latest(&dir).unwrap();
    let man = step_dir.join("manifest.json");
    let original = fs::read_to_string(&man).unwrap();
    fs::write(&man, original.replace("\"version\":1", "\"version\":7")).unwrap();
    let ok_cfg = base(0, true).resume(dir_s.clone()).build().unwrap();
    let err = format!("{:#}", train(&sess, &ok_cfg).unwrap_err());
    assert!(err.contains("version 7"), "{err}");
    fs::write(&man, &original).unwrap();

    // truncated page file
    let bin_path = step_dir.join("state.bin");
    let bin = fs::read(&bin_path).unwrap();
    fs::write(&bin_path, &bin[..bin.len() - 9]).unwrap();
    let err = format!("{:#}", train(&sess, &ok_cfg).unwrap_err());
    assert!(err.contains("truncated"), "{err}");

    // single flipped bit
    let mut flipped = bin.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x01;
    fs::write(&bin_path, &flipped).unwrap();
    let err = format!("{:#}", train(&sess, &ok_cfg).unwrap_err());
    assert!(err.contains("CRC"), "{err}");

    // intact bytes resume fine again
    fs::write(&bin_path, &bin).unwrap();
    train(&sess, &ok_cfg).expect("pristine checkpoint resumes");
    fs::remove_dir_all(&dir).unwrap();
}

/// Seeded dropout: identical across repeats AND across thread modes,
/// with the run ledger matching the pure schedule and dropped workers
/// consuming no tokens.
#[test]
fn dropout_runs_are_deterministic_and_account_honestly() {
    let sess = sess();
    // pick a seed whose schedule actually drops someone in 3 windows
    let faulty = |seed: u64, parallel: bool| {
        base(0, parallel)
            .dropout(0.35)
            .fault_seed(seed)
            .build()
            .unwrap()
    };
    let seed = (0..100u64)
        .find(|&s| {
            let plan = FaultPlan::for_run(&faulty(s, true)).unwrap();
            (1..=3u64).any(|w| plan.mask(w, 4).iter().any(|&a| !a))
        })
        .expect("some seed under p=0.35 drops a worker in 3 windows");

    let a = train(&sess, &faulty(seed, true)).unwrap();
    let b = train(&sess, &faulty(seed, true)).unwrap();
    assert_same(&a, &b, "repeat");
    let s = train(&sess, &faulty(seed, false)).unwrap();
    assert_same(&a, &s, "parallel vs sequential under dropout");

    // the ledger equals the pure schedule's arithmetic
    let plan = FaultPlan::for_run(&faulty(seed, true)).unwrap();
    let expected_drops: u64 = (1..=3u64)
        .map(|w| plan.mask(w, 4).iter().filter(|&&x| !x).count() as u64)
        .sum();
    assert_eq!(a.faults.rounds, 3);
    assert_eq!(a.faults.dropped, expected_drops);
    assert!(expected_drops > 0, "seed search guaranteed a drop");

    // dropped workers take no inner steps: fewer tokens than fault-free
    let clean = train(&sess, &base(0, true).build().unwrap()).unwrap();
    assert!(a.tokens < clean.tokens, "{} vs {}", a.tokens, clean.tokens);
    assert_eq!(clean.faults.dropped, 0);
    assert_ne!(a.eval_curve, clean.eval_curve,
               "dropout must change the trajectory, not crash it");
}

/// Synthetic boundary: with eta=1, mu=0 the outer step lands exactly on
/// the mean of the SURVIVING workers — the renormalization the elastic
/// sync owes the pseudogradient (dividing by K with a worker missing
/// would shrink Psi toward zero).
#[test]
fn masked_boundary_renormalizes_the_pseudogradient_over_survivors() {
    let corpus = Corpus::new(16, 1);
    let metas = vec![SyncTensorMeta::from_shape(&[4], 4)];
    let mk = |v: f32| {
        Worker::new(vec![vec![v; 4]], Vec::new(), corpus.shard(0),
                    ErrorFeedback::new(1, 1.0))
    };
    // worker 1 holds a wild replica; it is dropped this round
    let mut workers = vec![mk(1.0), mk(100.0), mk(3.0)];
    let outer = NesterovOuter::new(1.0, 0.0, &[4]);
    let mut engine = SyncEngine::from_parts(
        SyncPlan::dense(1, 1), metas, outer, Compression::None, false);
    let mut theta = vec![vec![0.0f32; 4]];
    let mut comm = CommStats::default();
    engine.sync_step_masked(1, &mut theta, &mut workers, &mut comm, false,
                            Some(&[true, false, true]));
    // Psi = mean over survivors of (theta - theta_k) = -(1+3)/2 = -2,
    // so theta' = 0 - 1*(-2) = 2 — the survivor mean, untouched by the
    // dropped replica's 100.0
    for x in &theta[0] {
        assert!((x - 2.0).abs() < 1e-6, "theta = {x}, want survivor mean 2.0");
    }
    // the dropped worker rejoined from the boundary broadcast
    assert_eq!(workers[1].params, theta);
    // and the comm ledger priced 2 participants, not 3
    assert!(comm.total_bytes > 0);
    assert_eq!(comm.sent_per_rank.len(), 3);
    assert_eq!(comm.sent_per_rank[1], 0, "dropped rank must not be charged");
}

/// Checkpoint/resume composes with `--precision bf16`: the storage
/// rounding is part of the replayed math (the run contract stays
/// BitExact — see `runtime::native::tier::contract_for_run`), so a
/// killed bf16 run must resume onto the uninterrupted trajectory byte
/// for byte, exactly like f32.
#[test]
fn resume_under_bf16_is_bit_for_bit() {
    use muloco::runtime::Precision;
    let sess = sess();
    if sess.set_precision(Precision::Bf16).is_err() {
        eprintln!("backend has no bf16 storage mode; skipping");
        return;
    }
    sess.set_precision(Precision::F32).expect("reset precision");
    let spec = || base(0, true).precision(Precision::Bf16);
    let full = train(&sess, &spec().build().unwrap()).unwrap();
    let dir = tmp("bf16resume");
    let dir_s = dir.to_string_lossy().to_string();
    let halted = spec()
        .save_every(4)
        .ckpt_dir(dir_s.clone())
        .halt_after(8)
        .build()
        .unwrap();
    train(&sess, &halted).unwrap();
    let resumed = train(&sess, &spec().resume(dir_s).build().unwrap()).unwrap();
    assert_same(&full, &resumed, "bf16 + resume");
    fs::remove_dir_all(&dir).unwrap();
}

/// Checkpoint/resume composes with fault injection: the ledger and the
/// trajectory both survive the restart.
#[test]
fn resume_under_faults_is_bit_for_bit() {
    let sess = sess();
    let spec = || base(0, true).dropout(0.4).fault_seed(3);
    let full = train(&sess, &spec().build().unwrap()).unwrap();
    let dir = tmp("faultresume");
    let dir_s = dir.to_string_lossy().to_string();
    let halted = spec()
        .save_every(4)
        .ckpt_dir(dir_s.clone())
        .halt_after(8)
        .build()
        .unwrap();
    train(&sess, &halted).unwrap();
    let resumed = train(&sess, &spec().resume(dir_s).build().unwrap()).unwrap();
    assert_same(&full, &resumed, "dropout + resume");
    fs::remove_dir_all(&dir).unwrap();
}
