//! Manifest + artifact contract tests (no PJRT needed for most).

use std::fs;

use muloco::runtime::{Manifest, TensorKind};
use muloco::util::json::Json;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let p = std::path::PathBuf::from("artifacts/nano");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("artifacts missing; run `make artifacts` (test skipped)");
        None
    }
}

#[test]
fn manifest_parses_and_validates() {
    let Some(dir) = artifacts_dir() else { return };
    let man = Manifest::load(&dir).unwrap();
    assert_eq!(man.config.name, "nano");
    let total: usize = man.params.iter().map(|p| p.size).sum();
    assert_eq!(total, man.config.param_count);
    assert!(man.params.iter().any(|p| p.kind == TensorKind::Hidden));
    assert_eq!(man.n_partitions(), 3);
    // every executable file exists and is HLO text
    for name in ["init", "fwd_grad", "apply_adamw", "apply_muon", "eval_step"] {
        let path = man.exe_path(name).unwrap();
        let head: String = fs::read_to_string(path).unwrap()
            .chars().take(9).collect();
        assert_eq!(head, "HloModule", "{name}");
    }
}

#[test]
fn manifest_partitions_cover_all_params() {
    let Some(dir) = artifacts_dir() else { return };
    let man = Manifest::load(&dir).unwrap();
    let mut seen = vec![false; man.params.len()];
    for part in 0..man.n_partitions() {
        for idx in man.partition_indices(part) {
            assert!(!seen[idx], "tensor in two partitions");
            seen[idx] = true;
        }
    }
    assert!(seen.iter().all(|s| *s));
}

#[test]
fn corrupt_manifest_is_rejected() {
    let tmp = std::env::temp_dir().join(format!("muloco-man-{}", std::process::id()));
    fs::create_dir_all(&tmp).unwrap();
    // (a) invalid JSON
    fs::write(tmp.join("manifest.json"), "{not json").unwrap();
    assert!(Manifest::load(&tmp).is_err());
    // (b) valid JSON, inconsistent param_count
    let Some(dir) = artifacts_dir() else {
        fs::remove_dir_all(&tmp).ok();
        return;
    };
    let text = fs::read_to_string(dir.join("manifest.json")).unwrap();
    let mut v = Json::parse(&text).unwrap();
    if let Json::Obj(m) = &mut v {
        if let Some(Json::Obj(cfg)) = m.get_mut("config") {
            cfg.insert("param_count".into(), Json::Num(1.0));
        }
    }
    fs::write(tmp.join("manifest.json"), v.to_string()).unwrap();
    let err = Manifest::load(&tmp).unwrap_err().to_string();
    assert!(err.contains("disagree"), "{err}");
    fs::remove_dir_all(&tmp).ok();
}

#[test]
fn manifest_missing_executable_is_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let tmp = std::env::temp_dir().join(format!("muloco-man2-{}", std::process::id()));
    fs::create_dir_all(&tmp).unwrap();
    let text = fs::read_to_string(dir.join("manifest.json")).unwrap();
    let mut v = Json::parse(&text).unwrap();
    if let Json::Obj(m) = &mut v {
        if let Some(Json::Obj(exes)) = m.get_mut("executables") {
            exes.remove("apply_muon");
        }
    }
    fs::write(tmp.join("manifest.json"), v.to_string()).unwrap();
    let err = Manifest::load(&tmp).unwrap_err().to_string();
    assert!(err.contains("apply_muon"), "{err}");
    fs::remove_dir_all(&tmp).ok();
}
