//! Correctness contract of the native execution backend.
//!
//! * backend selection: the default build loads a session with no
//!   artifacts on disk (manifest synthesized from the built-in ladder);
//! * finite-difference gradient checks for `fwd_grad` on the nano
//!   config (per-tensor directional derivatives, rel. err < 1e-2);
//! * closed-form checks for the optimizer kernels, including the
//!   `--ns-iters 0` degeneration of Muon to normalized momentum SGD;
//! * a blocked-vs-naive GEMM equivalence property test over random
//!   shapes.
//!
//! (The bit-for-bit parallel==sequential train contract lives in
//! tests/parallel_determinism.rs, now un-skipped on this backend.)

use std::path::PathBuf;

use muloco::data::Corpus;
use muloco::runtime::native::gemm::{sgemm, sgemm_naive, sgemm_nt, sgemm_tn,
                                    transpose_copy};
use muloco::runtime::{ModelDims, Session};
use muloco::util::rng::Rng;

fn native_session(model: &str) -> Session {
    // a directory that does not exist: forces manifest synthesis +
    // native backend on every build configuration
    let dir = PathBuf::from("no-such-artifacts").join(model);
    Session::load(&dir).expect("native session")
}

#[test]
fn default_build_selects_a_runnable_backend_without_artifacts() {
    let sess = native_session("nano");
    assert_eq!(sess.manifest.config.name, "nano");
    assert_eq!(sess.manifest.config.param_count, 41_824);
    assert_eq!(sess.manifest.n_partitions(), 3);
    // the whole built-in ladder synthesizes and validates
    for name in ModelDims::builtin_names() {
        let man = muloco::runtime::Manifest::synthesize(
            &PathBuf::from("x").join(name)).expect("synthesize");
        assert_eq!(&man.config.name, name);
        let total: usize = man.params.iter().map(|p| p.size).sum();
        assert_eq!(total, man.config.param_count, "{name}");
    }
    // unknown names fail with a helpful message
    let err = Session::load(&PathBuf::from("no-such-artifacts/mystery"));
    assert!(err.is_err());
    assert!(format!("{:#}", err.unwrap_err()).contains("built-in"));
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let sess = native_session("nano");
    let a = sess.init_params(7).unwrap();
    let b = sess.init_params(7).unwrap();
    let c = sess.init_params(8).unwrap();
    assert_eq!(a, b);
    assert_ne!(a, c);
    // norms at 1, embed small, matrices fan-in scaled
    for (p, spec) in a.iter().zip(&sess.manifest.params) {
        if spec.shape.len() == 1 {
            assert!(p.iter().all(|&x| x == 1.0), "{}", spec.name);
        } else {
            let ms: f64 = p.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
                / p.len() as f64;
            assert!(ms > 0.0 && ms < 0.1, "{}: mean square {ms}", spec.name);
        }
    }
}

/// Central-difference directional derivative per tensor, along the
/// (normalized) gradient direction: the analytic value is then exactly
/// the gradient norm.  Loss reduces in f64 inside the backend, which
/// keeps the FD noise floor well under the 1e-2 bar.
#[test]
fn fwd_grad_passes_finite_difference_checks() {
    let sess = native_session("nano");
    let cfg = sess.manifest.config.clone();
    let params = sess.init_params(3).unwrap();
    let corpus = Corpus::new(cfg.vocab, 5);
    let tokens = corpus.shard(0).next_batch(cfg.microbatch, cfg.seq_len);
    let (_, grads) = sess.fwd_grad(&params, &tokens).unwrap();

    let loss_at = |p: &Vec<Vec<f32>>| -> f64 {
        sess.fwd_grad(p, &tokens).unwrap().0 as f64
    };

    // whole-gradient direction: one strong aggregate check
    let gnorm: f64 = grads
        .iter()
        .flat_map(|g| g.iter())
        .map(|&x| (x as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    assert!(gnorm > 1e-2, "degenerate gradient {gnorm}");
    let h = 4e-3f64;
    let perturb = |sign: f64| -> Vec<Vec<f32>> {
        params
            .iter()
            .zip(&grads)
            .map(|(p, g)| {
                p.iter()
                    .zip(g)
                    .map(|(&pv, &gv)| {
                        (pv as f64 + sign * h * gv as f64 / gnorm) as f32
                    })
                    .collect()
            })
            .collect()
    };
    let fd = (loss_at(&perturb(1.0)) - loss_at(&perturb(-1.0))) / (2.0 * h);
    let rel = (fd - gnorm).abs() / gnorm;
    assert!(rel < 1e-2, "global FD check: fd {fd} vs |g| {gnorm} (rel {rel})");

    // per-tensor directions: catches a wrong gradient in any one tensor
    let mut checked = 0;
    for (ti, spec) in sess.manifest.params.iter().enumerate() {
        let tn: f64 = grads[ti]
            .iter()
            .map(|&x| (x as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        if tn < 5e-2 {
            continue; // FD noise would swamp a tiny directional slope
        }
        let mut plus = params.clone();
        let mut minus = params.clone();
        for i in 0..plus[ti].len() {
            let step = h * grads[ti][i] as f64 / tn;
            plus[ti][i] = (params[ti][i] as f64 + step) as f32;
            minus[ti][i] = (params[ti][i] as f64 - step) as f32;
        }
        let fd = (loss_at(&plus) - loss_at(&minus)) / (2.0 * h);
        let rel = (fd - tn).abs() / tn;
        assert!(
            rel < 1e-2,
            "tensor {} ({}): fd {fd} vs |g| {tn} (rel {rel})",
            ti, spec.name
        );
        checked += 1;
    }
    assert!(checked >= 5, "only {checked} tensors had checkable gradients");
}

#[test]
fn eval_step_agrees_with_fwd_grad_loss() {
    let sess = native_session("nano");
    let cfg = sess.manifest.config.clone();
    let params = sess.init_params(11).unwrap();
    let corpus = Corpus::new(cfg.vocab, 2);
    let tokens = corpus.shard(1).next_batch(cfg.microbatch, cfg.seq_len);
    let (loss_g, _) = sess.fwd_grad(&params, &tokens).unwrap();
    let (loss_e, acc) = sess.eval_step(&params, &tokens).unwrap();
    assert!((loss_g - loss_e).abs() < 1e-5, "{loss_g} vs {loss_e}");
    assert!((0.0..=1.0).contains(&acc));
    // a fresh model's loss sits near ln(vocab)
    let ln_v = (cfg.vocab as f32).ln();
    assert!((loss_e - ln_v).abs() < 1.2, "{loss_e} vs ln V {ln_v}");
}

/// ns_iters = 0 turns the Muon branch into momentum SGD with a
/// Frobenius-normalized direction: p' = p - lr*scale*m/(|m|+eps)
/// - lr*wd*p, with m = beta*0 + g on the first step.
#[test]
fn ns_iters_zero_degrades_muon_to_momentum_sgd() {
    let sess = native_session("nano");
    let cfg = sess.manifest.config.clone();
    let params = sess.init_params(4).unwrap();
    let corpus = Corpus::new(cfg.vocab, 9);
    let tokens = corpus.shard(0).next_batch(cfg.microbatch, cfg.seq_len);
    let (_, grads) = sess.fwd_grad(&params, &tokens).unwrap();
    let state = sess.zero_muon_state();
    let (lr, wd) = (0.05f32, 0.1f32);
    let (new_p, new_s) = sess
        .apply_muon_ns(&params, &state, &grads, 1.0, lr, wd, 0)
        .unwrap();

    let hidden = &sess.manifest.muon_hidden_indices;
    for (j, &pi) in hidden.iter().enumerate() {
        // momentum state is exactly the gradient on step 1
        assert_eq!(new_s[j], grads[pi], "momentum of tensor {pi}");
        let spec = &sess.manifest.params[pi];
        let (rows, cols) = (spec.shape[0], spec.shape[1]);
        let scale = (cols as f64 / rows as f64).sqrt();
        let norm: f64 = grads[pi]
            .iter()
            .map(|&x| (x as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let inv = 1.0 / (norm as f32 + 1e-7);
        for i in 0..new_p[pi].len() {
            let want = params[pi][i]
                - lr * scale as f32 * grads[pi][i] * inv
                - lr * wd * params[pi][i];
            let got = new_p[pi][i];
            assert!(
                (got - want).abs() <= 1e-6 + 1e-4 * want.abs(),
                "tensor {pi} elem {i}: {got} vs {want}"
            );
        }
    }
}

/// Muon with the default depth must still move hidden params along an
/// orthogonalized (not raw-momentum) direction, and route embed/head/
/// norms through AdamW.
#[test]
fn muon_state_layout_and_adamw_routing() {
    let sess = native_session("nano");
    let cfg = sess.manifest.config.clone();
    let params = sess.init_params(6).unwrap();
    let corpus = Corpus::new(cfg.vocab, 3);
    let tokens = corpus.shard(0).next_batch(cfg.microbatch, cfg.seq_len);
    let (_, grads) = sess.fwd_grad(&params, &tokens).unwrap();
    let state = sess.zero_muon_state();
    let (new_p, new_s) = sess
        .apply_muon(&params, &state, &grads, 1.0, 0.05, 0.0)
        .unwrap();
    assert_eq!(new_s.len(), sess.manifest.muon_state.len());
    // every parameter moved
    for (ti, (np, op)) in new_p.iter().zip(&params).enumerate() {
        assert_ne!(np, op, "tensor {ti} untouched");
    }
    // AdamW branch: with zero state and t=1 the update is lr * sign-ish
    // (|update| <= lr * bc1-corrected bound); just check norms moved at
    // the AdamW magnitude, not the Muon one
    let embed_delta: f32 = new_p[0]
        .iter()
        .zip(&params[0])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(embed_delta <= 0.05 * 1.01, "embed moved {embed_delta}");
}

#[test]
fn adamw_matches_closed_form_and_masks_decay() {
    let sess = native_session("nano");
    let params = sess.init_params(1).unwrap();
    let state = sess.zero_adamw_state();
    // zero grads isolate the decay term
    let grads: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
    let (lr, wd) = (0.1f32, 0.5f32);
    let (new_p, _) = sess
        .apply_adamw(&params, &state, &grads, 1.0, lr, wd)
        .unwrap();
    for (ti, spec) in sess.manifest.params.iter().enumerate() {
        if spec.shape.len() == 2 {
            // pure decay: p' = p * (1 - lr*wd)
            for (a, b) in new_p[ti].iter().zip(&params[ti]) {
                assert!((a - b * (1.0 - lr * wd)).abs() < 1e-6, "{}", spec.name);
            }
        } else {
            // 1-D tensors are excluded from decay and have zero grads
            assert_eq!(new_p[ti], params[ti], "{}", spec.name);
        }
    }
}

/// Property test: the blocked lane-parallel kernel and the naive
/// reference agree with an f64 oracle over random (incl. awkward)
/// shapes, and the transposed variants compose consistently.
#[test]
fn gemm_blocked_matches_naive_property() {
    let mut rng = Rng::new(31);
    for trial in 0..12 {
        let m = 1 + rng.below(70);
        let n = 1 + rng.below(70);
        let k = 1 + rng.below(300);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let mut oracle = vec![0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f64;
                for k_ in 0..k {
                    s += a[i * k + k_] as f64 * b[k_ * n + j] as f64;
                }
                oracle[i * n + j] = s;
            }
        }
        let check = |c: &[f32], label: &str| {
            for (i, (g, w)) in c.iter().zip(&oracle).enumerate() {
                let tol = 1e-5 * (k as f64).sqrt() * (1.0 + w.abs());
                assert!(
                    ((*g as f64) - w).abs() <= tol,
                    "trial {trial} {label}[{i}] ({m}x{n}x{k}): {g} vs {w}"
                );
            }
        };
        let mut c = vec![0f32; m * n];
        sgemm(m, n, k, &a, &b, &mut c);
        check(&c, "blocked");
        let mut cn = vec![0f32; m * n];
        sgemm_naive(m, n, k, &a, &b, &mut cn);
        check(&cn, "naive");
        let bt = transpose_copy(k, n, &b);
        let mut cnt = vec![0f32; m * n];
        sgemm_nt(m, n, k, &a, &bt, &mut cnt);
        check(&cnt, "nt");
        let at = transpose_copy(m, k, &a);
        let mut ctn = vec![0f32; m * n];
        sgemm_tn(m, n, k, &at, &b, &mut ctn);
        check(&ctn, "tn");
    }
}
