//! Observability contracts (ISSUE 10).
//!
//! The obs layer must be *inert*: spans only read clocks and thread-
//! local counters, so turning tracing on cannot change a single bit of
//! training output, cannot change a cache key, and cannot allocate in
//! the steady state (the PR 8 zero-alloc contract holds with rings
//! recording).  This file pins all three, plus the export invariants
//! (balanced B/E pairs, per-thread monotonic timestamps, every
//! instrumented category present) and ring wraparound (whole spans are
//! evicted, never torn begin/end pairs).  A separate test drives the
//! serve SSE endpoint end to end over raw TCP.
//!
//! Tracing state is process-global, so everything that depends on the
//! enabled flag lives in ONE `#[test]`; the SSE test is agnostic to it.

use std::collections::{BTreeMap, BTreeSet};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;

use muloco::coordinator::{cache_key, inner_with, train, Method, RunSpec,
                          TrainConfig, WorkerPool};
use muloco::data::Corpus;
use muloco::obs;
use muloco::runtime::{Session, NS_STEPS};
use muloco::serve::{self, ServeConfig};
use muloco::util::alloc_stats::{self, CountingAlloc};
use muloco::util::json::Json;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn nano_session() -> Session {
    Session::load(&PathBuf::from("artifacts/nano")).expect("session")
}

/// A run that exercises every instrumented category: parallel lanes
/// (K=2), two sync boundaries (steps=10, H=5), tau-overlapped apply
/// (tau=2 -> overlap reduce / stall / apply), eval passes, and the
/// collective codec on each boundary.
fn traced_cfg() -> TrainConfig {
    let mut cfg = RunSpec::new("nano", Method::Muloco)
        .batch(16)
        .workers(2)
        .steps(10)
        .sync_interval(5)
        .tau(2)
        .eval_every(5)
        .eval_batches(1)
        .warmup(2)
        .seed(5)
        .build()
        .expect("cfg");
    cfg.parallel = true;
    cfg
}

/// Warmed sequential inner steps, counted on this thread only (the
/// alloc_steady.rs window, re-run here with tracing ENABLED).
fn sequential_window_allocs(sess: &Session) -> u64 {
    let cfg = sess.manifest.config.clone();
    let corpus = Corpus::new(cfg.vocab, 11);
    let inner = inner_with(Method::Muloco, NS_STEPS, 1);
    let theta = sess.init_params(7).expect("init");
    let mut pool = WorkerPool::new(sess, &corpus, inner.as_ref(), 1, 0.9, &theta);
    let batch_seqs = 2 * cfg.microbatch;
    // warmup grows arenas, scratch — and registers this thread's span
    // ring (the one alloc the obs layer ever does per thread)
    for t in 1..=2u64 {
        pool.step(sess, batch_seqs, t as f32, 1e-3, 0.0, false, None)
            .expect("warmup step");
    }
    let a0 = alloc_stats::thread_allocs();
    for t in 3..=10u64 {
        pool.step(sess, batch_seqs, t as f32, 1e-3, 0.0, false, None)
            .expect("measured step");
    }
    alloc_stats::thread_allocs() - a0
}

#[test]
fn tracing_is_inert_bit_exact_and_allocation_free() {
    let sess = nano_session();
    let cfg = traced_cfg();

    // --- 1. baseline with tracing off --------------------------------
    let key_off = cache_key(&cfg);
    let off = train(&sess, &cfg).expect("baseline run");

    // --- 2. identical run with tracing on: bit-exact outputs ---------
    obs::trace::enable_with_capacity(4096);
    let on = train(&sess, &cfg).expect("traced run");
    assert_eq!(off.eval_curve, on.eval_curve, "tracing changed eval curve");
    assert_eq!(off.train_curve, on.train_curve, "tracing changed train curve");
    assert_eq!(off.comm, on.comm, "tracing changed comm accounting");
    assert_eq!(off.final_params, on.final_params,
               "tracing changed final params");
    assert_eq!(key_off, cache_key(&cfg),
               "tracing is launcher-only and must never reach the key");

    // --- 3. zero-alloc steady state holds with rings recording -------
    let n = sequential_window_allocs(&sess);
    assert_eq!(
        n, 0,
        "{n} heap allocations in 8 warmed sequential inner steps with \
         tracing enabled (contract: zero — span records are written into \
         pre-reserved rings)"
    );

    // --- 4. export invariants ----------------------------------------
    let dumps = obs::trace::dump();
    let doc = obs::chrome::chrome_trace(&dumps);
    let parsed = Json::parse(&doc.to_string()).expect("well-formed JSON");
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "traced run produced no events");
    let mut depth: BTreeMap<i64, i64> = BTreeMap::new();
    let mut last_ts: BTreeMap<i64, f64> = BTreeMap::new();
    let mut cats: BTreeSet<String> = BTreeSet::new();
    for e in events {
        let ph = e.get("ph").unwrap().as_str().unwrap();
        let tid = e.get("tid").unwrap().as_f64().unwrap() as i64;
        match ph {
            "M" => {} // thread_name metadata
            "B" | "E" => {
                // per-thread events are emitted in sequence (= program)
                // order, so timestamps can never run backwards
                let ts = e.get("ts").unwrap().as_f64().unwrap();
                let last = last_ts.entry(tid).or_insert(0.0);
                assert!(ts >= *last, "tid {tid}: ts {ts} after {last}");
                *last = ts;
                let d = depth.entry(tid).or_insert(0);
                *d += if ph == "B" { 1 } else { -1 };
                assert!(*d >= 0, "tid {tid}: E without matching B");
                cats.insert(e.get("cat").unwrap().as_str().unwrap().into());
            }
            other => panic!("unexpected event phase {other:?}"),
        }
    }
    for (tid, d) in &depth {
        assert_eq!(*d, 0, "tid {tid}: {d} unclosed B events");
    }
    for want in ["step", "kernel", "sync", "collective", "overlap"] {
        assert!(cats.contains(want),
                "no {want} spans in the traced run: {cats:?}");
    }
    // the breakdown derived from the same dumps attributes real time
    let bd = obs::chrome::breakdown(&dumps);
    assert!(bd.get("compute_ns").unwrap().as_f64().unwrap() > 0.0,
            "no inner-step time attributed");

    // --- 5. wraparound keeps whole spans -----------------------------
    obs::trace::set_ring_capacity(64);
    std::thread::spawn(|| {
        obs::trace::label_thread("wrap-test");
        for i in 0..100u64 {
            let _s = obs::trace::span_with_arg(
                obs::trace::Category::Step, "wrap", i);
        }
    })
    .join()
    .expect("wrap thread");
    obs::trace::set_ring_capacity(obs::trace::DEFAULT_RING_CAPACITY);
    let dumps = obs::trace::dump();
    let d = dumps
        .iter()
        .find(|d| d.label == "wrap-test")
        .expect("wrap thread's ring outlives the thread");
    assert_eq!(d.records.len(), 64, "ring holds exactly its capacity");
    assert_eq!(d.dropped, 36, "eviction is counted");
    let args: Vec<u64> = d.records.iter().map(|r| r.arg).collect();
    assert_eq!(args, (36..100).collect::<Vec<u64>>(),
               "oldest-first snapshot of the newest spans");
    for r in &d.records {
        assert_eq!(r.name, "wrap");
        assert!(r.end_seq > r.begin_seq,
                "a record is always a complete begin/end pair");
        assert!(r.end_ns >= r.begin_ns);
    }
}

// ---------------------------------------------------------------------
// SSE: GET /runs/:id/events over raw TCP
// ---------------------------------------------------------------------

const SMOKE: &str = r#"{"model":"nano","method":"muloco","workers":2,
    "batch":8,"steps":4,"sync-interval":2,"eval-every":2,"eval-batches":1,
    "warmup":1,"seed":6}"#;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("muloco-obs-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// One-shot HTTP/1.1 exchange: (status, lowercased headers, body).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str)
        -> (u16, BTreeMap<String, String>, Vec<u8>) {
    let mut s = TcpStream::connect(addr).expect("connect");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).expect("request write");
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).expect("response read");
    let pos = buf
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("head/body split");
    let head = String::from_utf8_lossy(&buf[..pos]).into_owned();
    let body = buf[pos + 4..].to_vec();
    let mut lines = head.lines();
    let status: u16 = lines
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .expect("status line");
    let mut headers = BTreeMap::new();
    for l in lines {
        if let Some((k, v)) = l.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    (status, headers, body)
}

#[test]
fn sse_streams_progress_then_done() {
    let h = serve::start(ServeConfig {
        addr: "127.0.0.1:0".into(),
        jobs: 1,
        store_dir: tmp_dir("sse"),
        legacy_cache_dir: None,
        ..ServeConfig::default()
    })
    .expect("serve start");
    let addr = h.addr;

    // async submit, then attach to the stream while the run executes —
    // the condvar path; if the run settles first, the same exchange
    // still yields every line plus the done frame (wait_progress
    // returns status and tail atomically)
    let (status, headers, body) = http(addr, "POST", "/runs", SMOKE);
    assert!(status == 202 || status == 200,
            "{status}: {}", String::from_utf8_lossy(&body));
    let id = headers.get("x-muloco-id").expect("id header").clone();

    let (status, headers, body) =
        http(addr, "GET", &format!("/runs/{id}/events"), "");
    assert_eq!(status, 200);
    assert_eq!(headers.get("content-type").map(String::as_str),
               Some("text/event-stream"));
    assert!(headers.get("content-length").is_none(),
            "a stream must not advertise a length");
    assert_eq!(headers.get("connection").map(String::as_str), Some("close"));
    let text = String::from_utf8_lossy(&body).into_owned();
    assert!(text.contains("data: "), "no progress frames:\n{text}");
    assert!(text.contains("event: done\ndata: done\n\n"),
            "missing done handshake:\n{text}");

    // a second attach after completion replays the full tail + done
    let (status, _, body) =
        http(addr, "GET", &format!("/runs/{id}/events"), "");
    assert_eq!(status, 200);
    let text = String::from_utf8_lossy(&body).into_owned();
    assert!(text.contains("trained in") || text.contains("served from store"),
            "replay lost the history:\n{text}");
    assert!(text.contains("event: done"), "{text}");

    // unknown ids still 404
    let (status, _, _) = http(addr, "GET", "/runs/deadbeef/events", "");
    assert_eq!(status, 404);

    h.stop();
}
