//! End-to-end integration over the runtime + coordinator.
//!
//! Runs on whichever backend `Session::load` selects: the native
//! pure-Rust backend on the default build (no artifacts needed — this
//! test never skips), or PJRT when artifacts + the `pjrt` feature are
//! present.  All checks share one Session (XLA's LLVM jit is expensive
//! on the PJRT path), so this is a single #[test] running a scripted
//! sequence of scenarios.

use muloco::compress::Compression;
use muloco::coordinator::{branch_capture, dp_warmstart, evaluate, train,
                          Method, RunSpec, TrainConfig};
use muloco::data::Corpus;
use muloco::runtime::Session;

fn short_cfg(method: Method, k: usize) -> TrainConfig {
    let mut spec = RunSpec::new("nano", method)
        .batch(16)
        .steps(20)
        .sync_interval(5)
        .eval_every(5)
        .eval_batches(2)
        .warmup(2);
    if method.is_local_update() {
        spec = spec.workers(k);
    }
    spec.build().expect("short config is valid")
}

#[test]
fn end_to_end() {
    let dir = std::path::PathBuf::from("artifacts/nano");
    let sess = Session::load(&dir).expect("session");

    // --- determinism: same seed, same params --------------------------
    let p1 = sess.init_params(5).unwrap();
    let p2 = sess.init_params(5).unwrap();
    assert_eq!(p1, p2, "init must be deterministic");
    let p3 = sess.init_params(6).unwrap();
    assert_ne!(p1, p3, "seed must matter");

    // --- fresh model's loss ~ log(vocab) -------------------------------
    let corpus = Corpus::new(sess.manifest.config.vocab, 0);
    let batch = corpus.eval_shard().next_batch(
        sess.manifest.config.microbatch, sess.manifest.config.seq_len);
    let (loss, acc) = sess.eval_step(&p1, &batch).unwrap();
    let log_v = (sess.manifest.config.vocab as f32).ln();
    assert!((loss - log_v).abs() < 1.2, "fresh loss {loss} vs ln V {log_v}");
    assert!((0.0..=1.0).contains(&acc));

    // --- every method trains and reduces loss --------------------------
    // (20 steps is enough to beat the untrained ~ln(V) loss; local
    // methods can oscillate between adjacent evals at this horizon, so
    // the bar is "well below untrained", not strict monotonicity)
    let mut finals = Vec::new();
    for method in [Method::DpAdamw, Method::DpMuon, Method::Diloco,
                   Method::Muloco] {
        let cfg = short_cfg(method, 2);
        let r = train(&sess, &cfg).expect("train");
        let last = r.eval_curve.last().unwrap().1;
        assert!(last < log_v as f64 - 0.2,
                "{method:?} did not learn: final {last} vs ln V {log_v}");
        assert!(last.is_finite());
        assert_eq!(r.tokens,
                   cfg.total_steps * (cfg.global_batch * 64) as u64);
        finals.push((method, last));
        // DP methods move no bytes; local methods do
        if method.is_local_update() {
            assert!(r.comm.bytes_per_worker > 0);
        } else {
            assert_eq!(r.comm.bytes_per_worker, 0);
        }
    }

    // --- training is deterministic end-to-end --------------------------
    let cfg = short_cfg(Method::Muloco, 2);
    let a = train(&sess, &cfg).unwrap();
    let b = train(&sess, &cfg).unwrap();
    assert_eq!(a.eval_curve, b.eval_curve, "training must be reproducible");

    // --- streaming J=... hits the same loss ballpark -------------------
    let mut cfg_s = short_cfg(Method::Muloco, 2);
    cfg_s.streaming_partitions = 5; // J must divide H = 5
    let err = cfg_s.validate();
    assert!(err.is_ok(), "{err:?}");
    let streamed = train(&sess, &cfg_s).unwrap();
    assert!(streamed.eval_curve.last().unwrap().1.is_finite());
    assert!(
        (streamed.smoothed_final - a.smoothed_final).abs() < 0.5,
        "streaming diverged: {} vs {}",
        streamed.smoothed_final, a.smoothed_final
    );

    // --- compression variants run and stay sane ------------------------
    for spec in ["q8-linear", "q4-stat", "q2-linear-rw", "topk0.1"] {
        let mut cfg_c = short_cfg(Method::Muloco, 2);
        cfg_c.compression = Compression::parse(spec).unwrap();
        cfg_c.error_feedback = spec.starts_with("topk");
        let r = train(&sess, &cfg_c).unwrap();
        let fin = r.eval_curve.last().unwrap().1;
        assert!(fin.is_finite(), "{spec}");
        assert!(fin < log_v as f64 + 0.5, "{spec} loss exploded: {fin}");
        // compressed bytes strictly below fp32 collective bytes
        assert!(r.comm.bytes_per_worker < a.comm.bytes_per_worker * 3,
                "{spec}");
    }

    // --- probe capture shapes -------------------------------------------
    let ckpt = dp_warmstart(&sess, Method::DpMuon, 4, 8, 0.05, 0.1, 1).unwrap();
    let cap = branch_capture(&sess, Method::Muloco, &ckpt, 2, 3, 8,
                             0.05, 0.1, 1).unwrap();
    assert_eq!(cap.worker_delta.len(), 2);
    assert_eq!(cap.step_updates[0].len(), 3);
    assert_eq!(cap.pseudograd.len(), cap.hidden_idx.len());
    // pseudograd really is the mean of worker deltas
    for ti in 0..cap.n_tensors() {
        for (i, p) in cap.pseudograd[ti].iter().enumerate() {
            let want = 0.5 * (cap.worker_delta[0][ti][i]
                              + cap.worker_delta[1][ti][i]);
            assert!((p - want).abs() < 1e-6);
        }
    }

    // --- evaluate() averages over batches -------------------------------
    let batches = vec![batch.clone(), batch];
    let (l2, _) = evaluate(&sess, &p1, &batches).unwrap();
    assert!((l2 - loss as f64).abs() < 1e-5);
}
