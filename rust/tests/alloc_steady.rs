//! The zero-allocation steady-state contract (ISSUE 8).
//!
//! After a short warmup that grows the step arena, the worker's grad
//! scratch and the optimizer workspaces, the inner step —
//! `accumulate_grads_into` + `InnerOptimizer::step_in_place` — must not
//! touch the heap at all.  This file installs the counting allocator
//! (`util::alloc_stats::CountingAlloc`; the library deliberately never
//! installs one) and pins:
//!
//! * the **sequential** path to *exactly zero* allocations per step,
//!   via the per-thread counter (immune to any other thread), for both
//!   inner optimizers and both storage precisions;
//! * the **parallel K=2** path to a small fixed budget over the whole
//!   measurement window, via the process-global counter.  The lanes'
//!   inner steps are the same zero-alloc code; what remains is the step
//!   barrier itself — three small `Vec`s on the main thread per step
//!   (the parked/losses/reassembled worker vectors) plus the mpsc
//!   channels' internal node/block allocations, whose exact count is a
//!   std implementation detail.  The budget is far below what any real
//!   regression costs: one re-introduced per-tensor clone in the hot
//!   loop adds K * n_tensors * steps allocations and blows through it
//!   immediately.
//!
//! Everything is measured in ONE `#[test]` so no sibling test thread
//! in this process can contribute to the global counter mid-window.

use std::path::PathBuf;

use muloco::coordinator::{inner_with, Method, WorkerPool};
use muloco::data::Corpus;
use muloco::runtime::{Precision, Session, NS_STEPS};
use muloco::util::alloc_stats::{self, CountingAlloc};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

const WARMUP_STEPS: u64 = 2;
const MEASURED_STEPS: u64 = 8;

/// Per-window allocation budget for the parallel path (see module doc:
/// 3 main-thread Vecs per step + channel internals, with headroom for
/// std's mpsc representation; a single hot-loop clone regression costs
/// hundreds).
const PARALLEL_WINDOW_BUDGET: u64 = 128;

fn nano_session() -> Session {
    // no artifacts on disk -> Session::load falls back to the native
    // backend, the one whose steady state this contract governs
    Session::load(&PathBuf::from("no-such-artifacts").join("nano"))
        .expect("native session")
}

/// Run `WARMUP_STEPS` then `MEASURED_STEPS` sequential inner steps and
/// return this thread's allocation count over the measured window.
fn sequential_window(sess: &Session, method: Method, precision: Precision) -> u64 {
    sess.set_precision(precision).expect("precision");
    let cfg = sess.manifest.config.clone();
    let corpus = Corpus::new(cfg.vocab, 11);
    let inner = inner_with(method, NS_STEPS, 1);
    let theta = sess.init_params(7).expect("init");
    let mut pool = WorkerPool::new(sess, &corpus, inner.as_ref(), 1, 0.9, &theta);
    // two microbatches per step, so the accumulator staging path
    // (micro_g + add_assign) is exercised, not just the direct landing
    let batch_seqs = 2 * cfg.microbatch;
    for t in 1..=WARMUP_STEPS {
        pool.step(sess, batch_seqs, t as f32, 1e-3, 0.0, false, None)
            .expect("warmup step");
    }
    let a0 = alloc_stats::thread_allocs();
    for t in WARMUP_STEPS + 1..=WARMUP_STEPS + MEASURED_STEPS {
        pool.step(sess, batch_seqs, t as f32, 1e-3, 0.0, false, None)
            .expect("measured step");
    }
    alloc_stats::thread_allocs() - a0
}

/// Same shape through the K=2 parallel engine (persistent lanes), with
/// the process-global counter — lane threads allocate on their own
/// threads, so the per-thread counter cannot see them.
fn parallel_window(sess: &Session, precision: Precision) -> u64 {
    sess.set_precision(precision).expect("precision");
    let cfg = sess.manifest.config.clone();
    let corpus = Corpus::new(cfg.vocab, 13);
    let inner = inner_with(Method::Muloco, NS_STEPS, 1);
    let theta = sess.init_params(7).expect("init");
    let mut pool = WorkerPool::new(sess, &corpus, inner.as_ref(), 2, 0.9, &theta);
    let batch_seqs = 2 * cfg.microbatch;
    pool.scoped(true, |pool| {
        for t in 1..=WARMUP_STEPS {
            pool.step(sess, batch_seqs, t as f32, 1e-3, 0.0, true, None)
                .expect("warmup step");
        }
        let a0 = alloc_stats::global_allocs();
        for t in WARMUP_STEPS + 1..=WARMUP_STEPS + MEASURED_STEPS {
            pool.step(sess, batch_seqs, t as f32, 1e-3, 0.0, true, None)
                .expect("measured step");
        }
        alloc_stats::global_allocs() - a0
    })
}

#[test]
fn steady_state_inner_steps_are_allocation_free() {
    let sess = nano_session();

    // --- sequential: exactly zero, per optimizer and precision -------
    for (method, label) in [(Method::Muloco, "muon"), (Method::Diloco, "adamw")] {
        let n = sequential_window(&sess, method, Precision::F32);
        assert_eq!(
            n, 0,
            "sequential {label}/f32: {n} heap allocations in \
             {MEASURED_STEPS} warmed inner steps (contract: zero)"
        );
    }
    if sess.set_precision(Precision::Bf16).is_ok() {
        for (method, label) in [(Method::Muloco, "muon"), (Method::Diloco, "adamw")] {
            let n = sequential_window(&sess, method, Precision::Bf16);
            assert_eq!(
                n, 0,
                "sequential {label}/bf16: {n} heap allocations in \
                 {MEASURED_STEPS} warmed inner steps (contract: zero)"
            );
        }
    }

    // --- parallel K=2: bounded by the barrier budget -----------------
    for precision in [Precision::F32, Precision::Bf16] {
        if sess.set_precision(precision).is_err() {
            continue;
        }
        let n = parallel_window(&sess, precision);
        assert!(
            n <= PARALLEL_WINDOW_BUDGET,
            "parallel K=2 {precision:?}: {n} heap allocations in \
             {MEASURED_STEPS} warmed steps exceeds the \
             {PARALLEL_WINDOW_BUDGET}-alloc window budget — something \
             in the inner step or the step barrier started allocating"
        );
    }

    // the arena actually carried the activations (a nonzero high-water
    // mark), so the zero counts above measured the arena path, not an
    // accidentally-bypassed one
    assert!(
        muloco::runtime::native::arena::global_peak_bytes() > 0,
        "step arena was never used — the zero-alloc counts are vacuous"
    );
}
