//! In-flight dedupe contract of `muloco serve` (ISSUE 9): a spec
//! submitted twice concurrently trains exactly once — the two
//! submitters observe byte-identical result bodies — while a distinct
//! spec trains independently; a re-submission after completion is a
//! store hit; truncated (`halt-after`) specs are rejected at the door.
//!
//! Talks to the real server over TCP with a hand-rolled HTTP/1.1
//! client, so the vendored `serve::http` layer is exercised end to end.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::thread;

use muloco::serve::{self, ServeConfig};

/// Small enough to train in seconds, fully pinned so the canonical key
/// is stable across submissions.
const SMOKE: &str = r#"{"model":"nano","method":"muloco","workers":2,
    "batch":8,"steps":4,"sync-interval":2,"eval-every":2,"eval-batches":1,
    "warmup":1,"seed":3}"#;

/// Same shape, different seed — a key knob, so a distinct execution.
const OTHER: &str = r#"{"model":"nano","method":"muloco","workers":2,
    "batch":8,"steps":4,"sync-interval":2,"eval-every":2,"eval-batches":1,
    "warmup":1,"seed":4}"#;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("muloco-serve-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn start_server(tag: &str) -> serve::ServeHandle {
    serve::start(ServeConfig {
        addr: "127.0.0.1:0".into(),
        jobs: 1, // serialize training so joins actually happen
        store_dir: tmp_dir(tag),
        // never absorb the repo's real results/cache into a test store
        legacy_cache_dir: None,
        ..ServeConfig::default()
    })
    .expect("serve start")
}

/// One-shot HTTP/1.1 exchange: (status, lowercased headers, body bytes).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str)
        -> (u16, BTreeMap<String, String>, Vec<u8>) {
    let mut s = TcpStream::connect(addr).expect("connect");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).expect("request write");
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).expect("response read");
    let pos = buf
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("response has a head/body split");
    let head = String::from_utf8_lossy(&buf[..pos]).into_owned();
    let body = buf[pos + 4..].to_vec();
    let mut lines = head.lines();
    let status: u16 = lines
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .expect("status line");
    let mut headers = BTreeMap::new();
    for l in lines {
        if let Some((k, v)) = l.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(),
                           v.trim().to_string());
        }
    }
    (status, headers, body)
}

fn metric(text: &str, name: &str) -> u64 {
    text.lines()
        .find_map(|l| l.strip_prefix(&format!("{name} ")))
        .unwrap_or_else(|| panic!("metric {name} missing in:\n{text}"))
        .trim()
        .parse()
        .expect("metric value")
}

#[test]
fn concurrent_identical_specs_train_once() {
    let h = start_server("dedupe");
    let addr = h.addr;

    // two identical specs + one distinct, all in flight at once
    let posts: Vec<_> = [SMOKE, SMOKE, OTHER]
        .into_iter()
        .map(|spec| {
            thread::spawn(move || http(addr, "POST", "/runs?wait=1", spec))
        })
        .collect();
    let results: Vec<_> =
        posts.into_iter().map(|t| t.join().expect("post thread")).collect();
    for (status, _, body) in &results {
        assert_eq!(*status, 200, "{}", String::from_utf8_lossy(body));
    }

    // both smoke submitters observe byte-identical store entry bytes
    assert_eq!(results[0].2, results[1].2,
               "identical specs must serve identical bytes");
    assert_ne!(results[0].2, results[2].2,
               "a distinct spec must train independently");
    let sources: Vec<&str> = results
        .iter()
        .map(|(_, h, _)| h.get("x-muloco-source").map(String::as_str)
            .expect("source header"))
        .collect();
    assert!(sources.iter().any(|s| *s == "trained"), "{sources:?}");
    assert!(sources.iter()
                .all(|s| matches!(*s, "trained" | "joined" | "store")),
            "{sources:?}");
    let smoke_id = results[0].1.get("x-muloco-id").expect("id header").clone();
    assert_eq!(results[0].1.get("x-muloco-id"), results[1].1.get("x-muloco-id"),
               "identical specs share one run id");
    assert_ne!(Some(&smoke_id), results[2].1.get("x-muloco-id"));

    // exactly one training execution per distinct key: 2 store writes
    let (status, _, body) = http(addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    let metrics = String::from_utf8_lossy(&body).into_owned();
    assert_eq!(metric(&metrics, "muloco_store_puts"), 2, "{metrics}");
    assert_eq!(metric(&metrics, "muloco_runs_failed"), 0, "{metrics}");
    assert_eq!(metric(&metrics, "muloco_queue_depth"), 0, "{metrics}");

    // a later identical submission is a pure store hit — same bytes,
    // no third training
    let (status, headers, body) = http(addr, "POST", "/runs?wait=1", SMOKE);
    assert_eq!(status, 200);
    assert_eq!(headers.get("x-muloco-source").map(String::as_str),
               Some("store"));
    assert_eq!(body, results[0].2);
    let (_, _, body) = http(addr, "GET", "/metrics", "");
    let metrics = String::from_utf8_lossy(&body).into_owned();
    assert_eq!(metric(&metrics, "muloco_store_puts"), 2,
               "a store hit must not retrain: {metrics}");
    assert!(metric(&metrics, "muloco_store_hits") >= 1, "{metrics}");

    // the id is a content address: status + result fetch by id
    let (status, _, body) =
        http(addr, "GET", &format!("/runs/{smoke_id}"), "");
    assert_eq!(status, 200);
    assert!(String::from_utf8_lossy(&body).contains("\"done\""));
    let (status, _, body) =
        http(addr, "GET", &format!("/runs/{smoke_id}/result"), "");
    assert_eq!(status, 200);
    assert_eq!(body, results[0].2);

    // registry listing round-trips
    let (status, _, body) = http(addr, "GET", "/experiments", "");
    assert_eq!(status, 200);
    assert!(String::from_utf8_lossy(&body).contains("fig1a"));

    h.stop();
}

#[test]
fn bad_specs_are_rejected_at_submit() {
    let h = start_server("reject");
    let addr = h.addr;

    // halt-after runs are truncated and must never enter the store
    let halted = r#"{"model":"nano","method":"muloco","workers":2,
        "batch":8,"steps":4,"sync-interval":2,"halt-after":2}"#;
    let (status, _, body) = http(addr, "POST", "/runs?wait=1", halted);
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&body));
    assert!(String::from_utf8_lossy(&body).contains("halt-after"));

    // malformed JSON and unknown fields fail canonicalization
    let (status, _, _) = http(addr, "POST", "/runs", "not json {");
    assert_eq!(status, 400);
    let (status, _, body) = http(addr, "POST", "/runs",
                                 r#"{"model":"nano","method":"muloco",
                                     "no-such-knob":1}"#);
    assert_eq!(status, 400);
    assert!(String::from_utf8_lossy(&body).contains("no-such-knob"));

    // nothing entered the store
    let (_, _, body) = http(addr, "GET", "/metrics", "");
    let metrics = String::from_utf8_lossy(&body).into_owned();
    assert_eq!(metric(&metrics, "muloco_store_puts"), 0, "{metrics}");

    h.stop();
}
