//! The run-spec schema contract (coordinator::spec):
//!
//! * perturb-every-knob: every registry knob that claims cache-key
//!   membership actually moves the key — the property that makes
//!   "new field silently aliases cache entries" unrepresentable;
//! * spec-file round-trip: `--spec` reproduces a flag-specified run
//!   bit-for-bit (same cache key, same final loss);
//! * builder defaulting/validation (the old tuned_outer/validate split,
//!   now a single `build()`);
//! * `ortho_interval = 1` is bit-identical to classic Muon.

use std::collections::BTreeSet;

use muloco::coordinator::spec::{cache_key, knobs, spec_json};
use muloco::coordinator::{train, Method, MuonInner, RunSpec, InnerOptimizer};
use muloco::experiments::cache_key_for_tests;
use muloco::runtime::{Session, NS_STEPS};

/// Every in-key knob perturbs the canonical key, for every method's
/// default baseline, and no two perturbations collide.
#[test]
fn every_knob_perturbs_the_cache_key() {
    for method in [Method::Muloco, Method::Diloco, Method::DpMuon,
                   Method::DpAdamw] {
        let base = RunSpec::new("nano", method).peek().clone();
        let base_key = cache_key(&base);
        let mut seen: BTreeSet<String> = BTreeSet::new();
        seen.insert(base_key.clone());
        for k in knobs() {
            let mut cfg = base.clone();
            (k.set)(&mut cfg, k.example)
                .unwrap_or_else(|e| panic!("knob {}: {e}", k.name));
            // a method's own name is that base's default; every other
            // example is required to differ from every method's default
            let changed = (k.get)(&cfg) != (k.get)(&base);
            assert!(changed || k.name == "method",
                    "{method:?}: knob {} example equals its default", k.name);
            let key = cache_key(&cfg);
            if k.in_key && changed {
                assert_ne!(key, base_key,
                           "{method:?}: knob {} does not move the key", k.name);
                assert!(seen.insert(key),
                        "{method:?}: knob {} collides with another knob's key",
                        k.name);
            } else if !k.in_key {
                assert_eq!(key, base_key,
                           "{method:?}: execution knob {} leaked into the key",
                           k.name);
            }
        }
    }
}

/// The experiments cache uses the registry key verbatim — no second
/// hand-maintained list behind `cache::config_key`.
#[test]
fn cache_config_key_is_the_registry_key() {
    let cfg = RunSpec::new("nano", Method::Muloco)
        .workers(4)
        .ns_iters(3)
        .ortho_interval(2)
        .build()
        .unwrap();
    assert_eq!(cache_key_for_tests(&cfg), cache_key(&cfg));
    // and the key mentions the new knobs (regression for the PR-3-era
    // "remember the |ns suffix by hand" failure mode)
    assert!(cache_key(&cfg).contains("ns3"));
    assert!(cache_key(&cfg).contains("r2"));
}

/// Flags -> build -> spec file -> build reproduces the exact config:
/// same cache key and, end-to-end on the native backend, the same
/// training trajectory bit-for-bit.
#[test]
fn spec_file_reproduces_a_flag_run_bit_for_bit() {
    let flag_cfg = RunSpec::new("nano", Method::Muloco)
        .batch(16)
        .workers(2)
        .steps(10)
        .sync_interval(5)
        .eval_every(5)
        .eval_batches(2)
        .warmup(2)
        .ns_iters(3)
        .build()
        .unwrap();
    let text = spec_json(&flag_cfg).to_string();
    let spec_cfg = RunSpec::from_json(&text).unwrap().build().unwrap();
    assert_eq!(cache_key(&spec_cfg), cache_key(&flag_cfg));

    let sess = Session::load(std::path::Path::new("artifacts/nano"))
        .expect("session");
    let a = train(&sess, &flag_cfg).expect("flag run");
    let b = train(&sess, &spec_cfg).expect("spec run");
    assert_eq!(a.eval_curve, b.eval_curve, "spec replay diverged");
    assert_eq!(a.train_curve, b.train_curve);
    assert_eq!(a.comm, b.comm);
}

/// A spec file pins every knob, so flag overrides on top of it change
/// exactly the overridden knob.
#[test]
fn spec_overrides_change_only_the_overridden_knob() {
    let cfg = RunSpec::new("nano", Method::Muloco).workers(4).build().unwrap();
    let text = spec_json(&cfg).to_string();
    let bumped = RunSpec::from_json(&text)
        .unwrap()
        .set("seed", "99")
        .unwrap()
        .build()
        .unwrap();
    assert_eq!(bumped.seed, 99);
    assert_eq!(bumped.workers, cfg.workers);
    assert_eq!(bumped.outer_lr, cfg.outer_lr,
               "tuned outer defaulting must not re-fire on a pinned spec");
}

/// `ortho_interval = 1` dispatches exactly like classic Muon: the same
/// (params, state, grads) produce bit-identical outputs against the
/// pre-knob `apply_muon` entry point, at several step indices.
#[test]
fn ortho_interval_one_is_bit_identical_to_classic_muon() {
    let sess = Session::load(std::path::Path::new("artifacts/nano"))
        .expect("session");
    let params = sess.init_params(3).unwrap();
    let cfg = &sess.manifest.config;
    let tokens: Vec<i32> = (0..cfg.microbatch * cfg.seq_len)
        .map(|i| (i * 13 % cfg.vocab) as i32)
        .collect();
    let (_, grads) = sess.fwd_grad(&params, &tokens).unwrap();
    let state = sess.zero_muon_state();
    let inner = MuonInner { ns_iters: NS_STEPS, ortho_interval: 1 };
    for t in [1.0f32, 2.0, 7.0] {
        let (p_new, s_new) = inner
            .step(&sess, &params, &state, &grads, t, 0.05, 0.0)
            .unwrap();
        let (p_ref, s_ref) = sess
            .apply_muon(&params, &state, &grads, t, 0.05, 0.0)
            .unwrap();
        assert_eq!(p_new, p_ref, "params diverged at t={t}");
        assert_eq!(s_new, s_ref, "state diverged at t={t}");
    }
    // r = 2, t = 2 is an off-step: identical to the ns = 0 dispatch
    let bp = MuonInner { ns_iters: NS_STEPS, ortho_interval: 2 };
    let (p_off, _) = bp.step(&sess, &params, &state, &grads, 2.0, 0.05, 0.0)
        .unwrap();
    let (p_sgd, _) = sess
        .apply_muon_ns(&params, &state, &grads, 2.0, 0.05, 0.0, 0)
        .unwrap();
    assert_eq!(p_off, p_sgd);
}

/// End-to-end: `ns_iters = 0` makes the ortho schedule irrelevant
/// (both dispatch to normalized momentum SGD on every step), while at
/// full depth `ortho_interval` changes the trajectory.
#[test]
fn ortho_interval_end_to_end_contract() {
    let sess = Session::load(std::path::Path::new("artifacts/nano"))
        .expect("session");
    let run = |ns: usize, r: usize| {
        let cfg = RunSpec::new("nano", Method::Muloco)
            .batch(16)
            .workers(2)
            .steps(8)
            .sync_interval(4)
            .eval_every(4)
            .eval_batches(1)
            .warmup(2)
            .ns_iters(ns)
            .ortho_interval(r)
            .build()
            .unwrap();
        train(&sess, &cfg).expect("train")
    };
    let sgd_r1 = run(0, 1);
    let sgd_r4 = run(0, 4);
    assert_eq!(sgd_r1.eval_curve, sgd_r4.eval_curve,
               "ns=0 must be schedule-independent");
    let full = run(NS_STEPS, 1);
    let periodic = run(NS_STEPS, 3);
    assert_ne!(full.train_curve, periodic.train_curve,
               "ortho_interval > 1 must change the trajectory");
}
