//! Property + regression tests for the `comm` subsystem refactor.
//!
//! * the retired `collectives` free functions (now shims over the
//!   `CollectiveOp` pipeline) must reproduce the pre-refactor
//!   implementations **bit-for-bit** — the originals are copied
//!   verbatim below as references;
//! * every `Topology` must reduce to the exact fp32 mean under
//!   `NoCompression`, with all workers in exact agreement;
//! * reported wire bytes must match `Compressor::wire_bytes`;
//! * overlapped streaming sync with tau = 0 must be bit-identical to
//!   the blocking path, tau > 0 must be deterministic (parallel ==
//!   sequential) and must apply exactly tau steps late; tau > stride
//!   (multiple boundaries in flight per group) must pin bit-for-bit to
//!   a longhand delayed-apply reference simulation;
//! * streaming must divide the measured *peak* per-event bytes by J
//!   while keeping the total volume unchanged.

use muloco::comm::{
    AllToAll, CollectiveOp, CommStats, Hierarchical, OpKind, Ring, Topology,
    TopologySpec,
};
use muloco::collectives::{
    quantized_reduce_mean, ring_allreduce_mean,
    ring_quantized_reduce_compounding, sparse_allgather_mean,
};
use muloco::compress::{
    Compression, Compressor, ErrorFeedback, NoCompression, QuantMode,
    Quantizer, TopK,
};
use muloco::coordinator::{
    NesterovOuter, SyncEngine, SyncPlan, SyncTensorMeta, Worker,
};
use muloco::data::Corpus;
use muloco::util::rng::Rng;

fn worker_buffers(k: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..k)
        .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
        .collect()
}

// ---- the pre-refactor free functions, verbatim (value semantics +
// ---- per-worker byte accounting), as regression references ----------

fn ref_ring_allreduce_mean(buffers: &mut [Vec<f32>]) -> usize {
    let k = buffers.len();
    let n = buffers[0].len();
    let mut mean = vec![0.0f32; n];
    for b in buffers.iter() {
        for (m, x) in mean.iter_mut().zip(b) {
            *m += x;
        }
    }
    let inv = 1.0 / k as f32;
    for m in mean.iter_mut() {
        *m *= inv;
    }
    for b in buffers.iter_mut() {
        b.copy_from_slice(&mean);
    }
    if k > 1 { 2 * (k - 1) * 4 * n / k } else { 0 }
}

fn ref_quantized_reduce_mean(
    buffers: &mut [Vec<f32>],
    compressor: &dyn Compressor,
    rows: usize,
    cols: usize,
) -> usize {
    let k = buffers.len();
    let n = buffers[0].len();
    let mut wire = 0usize;
    for b in buffers.iter_mut() {
        wire = compressor.compress(b, rows, cols);
    }
    let mut mean = vec![0.0f32; n];
    for b in buffers.iter() {
        for (m, x) in mean.iter_mut().zip(b) {
            *m += x;
        }
    }
    let inv = 1.0 / k as f32;
    for m in mean.iter_mut() {
        *m *= inv;
    }
    let _ = compressor.compress(&mut mean, rows, cols);
    for b in buffers.iter_mut() {
        b.copy_from_slice(&mean);
    }
    if k > 1 { 2 * (k - 1) * wire / k } else { 0 }
}

fn ref_sparse_allgather_mean(
    buffers: &mut [Vec<f32>],
    compressor: &dyn Compressor,
    rows: usize,
    cols: usize,
) -> usize {
    let k = buffers.len();
    let n = buffers[0].len();
    let mut wire = 0usize;
    for b in buffers.iter_mut() {
        wire = compressor.compress(b, rows, cols);
    }
    let mut mean = vec![0.0f32; n];
    for b in buffers.iter() {
        for (m, x) in mean.iter_mut().zip(b) {
            *m += x;
        }
    }
    let inv = 1.0 / k as f32;
    for m in mean.iter_mut() {
        *m *= inv;
    }
    for b in buffers.iter_mut() {
        b.copy_from_slice(&mean);
    }
    if k > 1 { (k - 1) * wire } else { 0 }
}

fn ref_ring_quantized_reduce_compounding(
    buffers: &mut [Vec<f32>],
    compressor: &dyn Compressor,
    rows: usize,
    cols: usize,
) -> usize {
    let k = buffers.len();
    let mut acc = buffers[0].clone();
    #[allow(unused_assignments)]
    let mut wire = compressor.compress(&mut acc, rows, cols);
    for b in buffers.iter().skip(1) {
        let mut contrib = b.clone();
        wire = compressor.compress(&mut contrib, rows, cols);
        for (a, c) in acc.iter_mut().zip(&contrib) {
            *a += c;
        }
        wire = compressor.compress(&mut acc, rows, cols);
    }
    let inv = 1.0 / k as f32;
    for a in acc.iter_mut() {
        *a *= inv;
    }
    let _ = compressor.compress(&mut acc, rows, cols);
    for b in buffers.iter_mut() {
        b.copy_from_slice(&acc);
    }
    if k > 1 { 2 * (k - 1) * wire / k } else { 0 }
}

#[test]
fn shims_reproduce_pre_refactor_collectives_bit_for_bit() {
    let compressors: Vec<Box<dyn Compressor>> = vec![
        Box::new(NoCompression),
        Box::new(Quantizer::new(4, QuantMode::Linear, false)),
        Box::new(Quantizer::new(8, QuantMode::Linear, true)),
        Box::new(Quantizer::new(2, QuantMode::Statistical, false)),
    ];
    for k in [1usize, 2, 4, 8, 16] {
        for (seed, (rows, cols)) in [(1u64, (1usize, 256usize)), (2, (8, 32))] {
            let base = worker_buffers(k, rows * cols, seed);

            let mut got = base.clone();
            let mut want = base.clone();
            let s = ring_allreduce_mean(&mut got);
            let w = ref_ring_allreduce_mean(&mut want);
            assert_eq!(got, want, "dense K={k}");
            assert_eq!(s.bytes_per_worker, w, "dense bytes K={k}");
            assert_eq!(s.total_bytes, w * k, "dense total K={k}");

            for c in &compressors {
                let mut got = base.clone();
                let mut want = base.clone();
                let s = quantized_reduce_mean(&mut got, c.as_ref(), rows, cols);
                let w = ref_quantized_reduce_mean(
                    &mut want, c.as_ref(), rows, cols);
                assert_eq!(got, want, "quant {} K={k}", c.name());
                assert_eq!(s.bytes_per_worker, w, "quant bytes {}", c.name());

                let mut got = base.clone();
                let mut want = base.clone();
                let s = ring_quantized_reduce_compounding(
                    &mut got, c.as_ref(), rows, cols);
                let w = ref_ring_quantized_reduce_compounding(
                    &mut want, c.as_ref(), rows, cols);
                assert_eq!(got, want, "ring-compound {} K={k}", c.name());
                assert_eq!(s.bytes_per_worker, w, "ring bytes {}", c.name());
            }

            let topk = TopK::new(0.1);
            let mut got = base.clone();
            let mut want = base;
            let s = sparse_allgather_mean(&mut got, &topk, rows, cols);
            let w = ref_sparse_allgather_mean(&mut want, &topk, rows, cols);
            assert_eq!(got, want, "sparse K={k}");
            assert_eq!(s.bytes_per_worker, w, "sparse bytes K={k}");
        }
    }
}

#[test]
fn every_topology_reduces_to_exact_mean_under_no_compression() {
    let topologies: Vec<Box<dyn Topology>> = vec![
        Box::new(Ring),
        Box::new(AllToAll),
        Box::new(Hierarchical::new(2)),
        Box::new(Hierarchical::new(4)),
        Box::new(Hierarchical::new(8)),
    ];
    let (k, n) = (8usize, 333usize);
    let base = worker_buffers(k, n, 7);
    let mut want = vec![0.0f64; n];
    for b in &base {
        for (w, x) in want.iter_mut().zip(b) {
            *w += *x as f64 / k as f64;
        }
    }
    for topo in &topologies {
        for kind in [
            OpKind::Dense,
            OpKind::SparseGather { presparsified: false },
        ] {
            let op = CollectiveOp::new(&NoCompression, kind);
            let mut bufs = base.clone();
            let trace = topo.reduce_mean(&mut bufs, &op, 1, n);
            for b in &bufs[1..] {
                assert_eq!(b, &bufs[0], "{} disagreement", topo.name());
            }
            for (x, w) in bufs[0].iter().zip(&want) {
                assert!(
                    (*x as f64 - w).abs() < 1e-5,
                    "{} {kind:?}: {x} vs {w}",
                    topo.name()
                );
            }
            assert!(trace.total_bytes() > 0, "{} moved no bytes", topo.name());
        }
    }
}

#[test]
fn reported_wire_bytes_match_compressor_wire_bytes() {
    let k = 8usize;
    let (rows, cols) = (16usize, 16usize);
    let n = rows * cols;

    // two-quant on the flat all-to-all: 2(K-1)/K of one compressed tensor
    for q in [
        Quantizer::new(4, QuantMode::Linear, false),
        Quantizer::new(8, QuantMode::Linear, true),
        Quantizer::new(2, QuantMode::Statistical, false),
    ] {
        let mut bufs = worker_buffers(k, n, 11);
        let op = CollectiveOp::new(&q, OpKind::TwoQuant);
        let stats = AllToAll.reduce_mean(&mut bufs, &op, rows, cols).stats();
        let wire = q.wire_bytes(n, rows);
        assert_eq!(stats.bytes_per_worker, 2 * (k - 1) * wire / k, "{}",
                   q.name());
    }

    // sparse gather: K-1 copies of one compressed tensor per worker
    let t = TopK::new(0.1);
    let wire = t.wire_bytes(n, rows);
    let mut bufs = worker_buffers(k, n, 12);
    let op = CollectiveOp::new(&t, OpKind::SparseGather { presparsified: false });
    let stats = Ring.reduce_mean(&mut bufs, &op, rows, cols).stats();
    assert_eq!(stats.bytes_per_worker, (k - 1) * wire);

    // presparsified (error-feedback) path: values untouched, but the
    // real compressor's wire bytes are still charged
    let mut bufs = worker_buffers(k, n, 13);
    let before = bufs.clone();
    let op = CollectiveOp::new(&t, OpKind::SparseGather { presparsified: true });
    let stats = Ring.reduce_mean(&mut bufs, &op, rows, cols).stats();
    assert_eq!(stats.bytes_per_worker, (k - 1) * wire);
    // the reduced value is the exact mean of the *unsparsified* inputs
    let mut exact = before[0].clone();
    for b in &before[1..] {
        for (e, x) in exact.iter_mut().zip(b) {
            *e += x;
        }
    }
    for e in exact.iter_mut() {
        *e *= 1.0 / k as f32;
    }
    for (x, w) in bufs[0].iter().zip(&exact) {
        assert!((x - w).abs() < 1e-6);
    }
}

// ---- engine-level harness (mirrors tests/parallel_determinism.rs) ---

fn metas() -> Vec<SyncTensorMeta> {
    vec![
        SyncTensorMeta::from_shape(&[8, 16], 128),
        SyncTensorMeta::from_shape(&[64], 64),
        SyncTensorMeta::from_shape(&[16, 4], 64),
        SyncTensorMeta::from_shape(&[32], 32),
        SyncTensorMeta::from_shape(&[96], 96),
    ]
}

fn rand_theta(rng: &mut Rng, metas: &[SyncTensorMeta]) -> Vec<Vec<f32>> {
    metas
        .iter()
        .map(|m| (0..m.size).map(|_| rng.normal_f32()).collect())
        .collect()
}

#[allow(clippy::too_many_arguments)]
fn build<'c>(
    corpus: &'c Corpus,
    k: usize,
    compression: Compression,
    ef: bool,
    j_parts: usize,
    h: u64,
    topology: TopologySpec,
    tau: u64,
) -> (SyncEngine, Vec<Vec<f32>>, Vec<Worker<'c>>) {
    let metas = metas();
    let mut rng = Rng::new(99);
    let theta = rand_theta(&mut rng, &metas);
    let workers: Vec<Worker<'c>> = (0..k)
        .map(|w| {
            let params: Vec<Vec<f32>> = theta
                .iter()
                .map(|t| t.iter().map(|x| x + 0.01 * rng.normal_f32()).collect())
                .collect();
            Worker::new(params, Vec::new(), corpus.shard(w as u64),
                        ErrorFeedback::new(metas.len(), 0.9))
        })
        .collect();
    let sizes: Vec<usize> = metas.iter().map(|m| m.size).collect();
    let outer = NesterovOuter::new(0.7, 0.9, &sizes);
    let plan = if j_parts <= 1 {
        SyncPlan::dense(h, metas.len())
    } else {
        let parts = vec![0usize, 1, 1, 2, 2];
        SyncPlan::streaming(h, j_parts, &parts, 3)
    };
    let engine = SyncEngine::from_parts(plan, metas, outer, compression, ef)
        .with_topology(topology)
        .with_overlap(tau);
    (engine, theta, workers)
}

fn drift(workers: &mut [Worker<'_>], round: u64) {
    for (w, worker) in workers.iter_mut().enumerate() {
        let mut rng = Rng::new(round * 1000 + w as u64);
        for t in worker.params.iter_mut() {
            for x in t.iter_mut() {
                *x += 0.02 * rng.normal_f32();
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_rounds(
    corpus: &Corpus,
    compression: Compression,
    ef: bool,
    j_parts: usize,
    topology: TopologySpec,
    tau: u64,
    parallel: bool,
) -> (Vec<Vec<f32>>, Vec<Vec<Vec<f32>>>, CommStats) {
    let h = if j_parts <= 1 { 4 } else { 8 };
    let (mut engine, mut theta, mut workers) =
        build(corpus, 4, compression, ef, j_parts, h, topology, tau);
    let mut comm = CommStats::default();
    for step in 1..=3 * h {
        drift(&mut workers, step);
        engine.sync_step(step, &mut theta, &mut workers, &mut comm, parallel);
    }
    engine.flush(&mut theta, &mut workers, &mut comm);
    let params = workers.iter().map(|w| w.params.clone()).collect();
    (theta, params, comm)
}

#[test]
fn overlap_tau_zero_is_bit_identical_to_blocking() {
    let corpus = Corpus::new(64, 3);
    for (compression, ef) in [
        (Compression::None, false),
        (Compression::Quant { bits: 4, mode: QuantMode::Linear, rowwise: false },
         true),
        (Compression::TopK { frac: 0.25 }, true),
    ] {
        for parallel in [false, true] {
            // tau = 0 takes the blocking code path; an engine built
            // without with_overlap is the blocking reference
            let blocking = {
                let h = 4;
                let (mut engine, mut theta, mut workers) = build(
                    &corpus, 4, compression.clone(), ef, 1, h,
                    TopologySpec::Flat, 0);
                let mut comm = CommStats::default();
                for step in 1..=3 * h {
                    drift(&mut workers, step);
                    engine.sync_step(step, &mut theta, &mut workers, &mut comm,
                                     parallel);
                }
                let params: Vec<Vec<Vec<f32>>> =
                    workers.iter().map(|w| w.params.clone()).collect();
                (theta, params, comm)
            };
            let tau0 = run_rounds(&corpus, compression.clone(), ef, 1,
                                  TopologySpec::Flat, 0, parallel);
            assert_eq!(blocking.0, tau0.0, "{compression:?} theta");
            assert_eq!(blocking.1, tau0.1, "{compression:?} workers");
            assert_eq!(blocking.2, tau0.2, "{compression:?} comm");
        }
    }
}

#[test]
fn overlapped_sync_is_deterministic_across_thread_modes() {
    let corpus = Corpus::new(64, 3);
    for (compression, ef) in [
        (Compression::None, false),
        (Compression::Quant { bits: 8, mode: QuantMode::Linear, rowwise: true },
         true),
        (Compression::TopK { frac: 0.25 }, false),
    ] {
        for j_parts in [1usize, 2] {
            // 6 exceeds the J=2 stride (H/J = 4) and even the J=1
            // boundary spacing: multiple boundaries stay in flight
            for tau in [0u64, 1, 3, 6] {
                for topology in [TopologySpec::Flat, TopologySpec::Hier { groups: 2 }]
                {
                    let seq = run_rounds(&corpus, compression.clone(), ef,
                                         j_parts, topology, tau, false);
                    let par = run_rounds(&corpus, compression.clone(), ef,
                                         j_parts, topology, tau, true);
                    let tag = format!(
                        "{compression:?} ef={ef} J={j_parts} tau={tau} \
                         topo={topology:?}"
                    );
                    assert_eq!(seq.0, par.0, "theta diverged: {tag}");
                    assert_eq!(seq.1, par.1, "workers diverged: {tag}");
                    assert_eq!(seq.2, par.2, "comm diverged: {tag}");
                }
            }
        }
    }
}

#[test]
fn overlapped_sync_applies_exactly_tau_steps_late() {
    let corpus = Corpus::new(64, 5);
    let (mut engine, mut theta, mut workers) = build(
        &corpus, 4, Compression::None, false, 1, 4, TopologySpec::Flat, 2);
    let before = theta.clone();
    let mut comm = CommStats::default();
    // boundary at step 4 launches the collective; theta stays fixed
    // until the result applies at step 6
    for step in 1..=5 {
        drift(&mut workers, step);
        engine.sync_step(step, &mut theta, &mut workers, &mut comm, true);
        assert_eq!(theta, before, "theta moved early at step {step}");
        assert_eq!(comm.bytes_per_worker, 0, "bytes charged early");
    }
    drift(&mut workers, 6);
    engine.sync_step(6, &mut theta, &mut workers, &mut comm, true);
    assert_ne!(theta, before, "overlapped boundary never applied");
    assert!(comm.bytes_per_worker > 0);
    // the apply also re-broadcast: every worker agrees with theta
    for w in &workers {
        assert_eq!(w.params, theta);
    }
}

#[test]
fn streaming_divides_measured_peak_event_bytes_by_j() {
    // six equal tensors across three partitions: J=3 streaming must
    // show exactly 1/3 of the dense per-event peak at equal total
    let metas: Vec<SyncTensorMeta> = (0..6)
        .map(|_| SyncTensorMeta::from_shape(&[64], 64))
        .collect();
    let corpus = Corpus::new(64, 9);
    let run = |j_parts: usize| -> CommStats {
        let mut rng = Rng::new(42);
        let theta_init = rand_theta(&mut rng, &metas);
        let mut theta = theta_init.clone();
        let mut workers: Vec<Worker<'_>> = (0..4)
            .map(|w| {
                let params: Vec<Vec<f32>> = theta_init
                    .iter()
                    .map(|t| {
                        t.iter().map(|x| x + 0.01 * rng.normal_f32()).collect()
                    })
                    .collect();
                Worker::new(params, Vec::new(), corpus.shard(w as u64),
                            ErrorFeedback::new(metas.len(), 0.9))
            })
            .collect();
        let sizes: Vec<usize> = metas.iter().map(|m| m.size).collect();
        let outer = NesterovOuter::new(0.7, 0.9, &sizes);
        let h = 6;
        let plan = if j_parts <= 1 {
            SyncPlan::dense(h, metas.len())
        } else {
            let parts = vec![0usize, 0, 1, 1, 2, 2];
            SyncPlan::streaming(h, j_parts, &parts, 3)
        };
        let mut engine = SyncEngine::from_parts(
            plan, metas.clone(), outer, Compression::None, false);
        let mut comm = CommStats::default();
        for step in 1..=2 * h {
            drift(&mut workers, step);
            engine.sync_step(step, &mut theta, &mut workers, &mut comm, true);
        }
        comm
    };
    let dense = run(1);
    let streamed = run(3);
    assert_eq!(dense.total_bytes, streamed.total_bytes,
               "streaming changed total volume");
    assert_eq!(dense.bytes_per_worker, streamed.bytes_per_worker);
    assert_eq!(dense.peak_event_bytes, 3 * streamed.peak_event_bytes,
               "dense {} vs streamed {}", dense.peak_event_bytes,
               streamed.peak_event_bytes);
}

// ---- tau > stride: multiple boundaries in flight per group ----------

/// One launched-but-unapplied boundary of the reference simulation.
struct RefPending {
    apply_step: u64,
    /// (tensor, reduced psi, event-fragment stats), ascending tensor
    tensors: Vec<(usize, Vec<f32>, CommStats)>,
}

/// Apply every reference boundary matured by `upto`, in launch order:
/// outer step per tensor ascending, one comm event per boundary,
/// broadcast of the touched tensors — the delayed-apply semantics
/// written out longhand.
#[allow(clippy::too_many_arguments)]
fn ref_apply(
    upto: u64,
    queue: &mut Vec<RefPending>,
    eta: f32,
    mu: f32,
    u: &mut [Vec<f32>],
    theta: &mut [Vec<f32>],
    workers: &mut [Worker<'_>],
    comm: &mut CommStats,
) {
    let mut rest = Vec::new();
    for p in queue.drain(..) {
        if p.apply_step > upto {
            rest.push(p);
            continue;
        }
        let mut event = CommStats::default();
        let mut touched = Vec::new();
        for (ti, psi, stats) in &p.tensors {
            NesterovOuter::step_slot(eta, mu, &mut u[*ti], &mut theta[*ti], psi);
            event.add(stats);
            touched.push(*ti);
        }
        comm.absorb_event(&event);
        for w in workers.iter_mut() {
            for &ti in &touched {
                w.params[ti].copy_from_slice(&theta[ti]);
            }
        }
    }
    *queue = rest;
}

/// Independent inline simulation of overlapped streaming sync: capture
/// deltas at the boundary, reduce them immediately (the reduce is a
/// pure function of the captured deltas, so *when* it runs cannot
/// matter), apply the result tau steps later in launch order.  Same
/// seeds and drift as `build`, no `SyncEngine` involved.
fn delayed_apply_reference(
    corpus: &Corpus,
    j_parts: usize,
    h: u64,
    tau: u64,
) -> (Vec<Vec<f32>>, Vec<Vec<Vec<f32>>>, CommStats) {
    let metas = metas();
    let mut rng = Rng::new(99);
    let mut theta = rand_theta(&mut rng, &metas);
    let mut workers: Vec<Worker<'_>> = (0..4)
        .map(|w| {
            let params: Vec<Vec<f32>> = theta
                .iter()
                .map(|t| t.iter().map(|x| x + 0.01 * rng.normal_f32()).collect())
                .collect();
            Worker::new(params, Vec::new(), corpus.shard(w as u64),
                        ErrorFeedback::new(metas.len(), 0.9))
        })
        .collect();
    let (eta, mu) = (0.7f32, 0.9f32);
    let mut u: Vec<Vec<f32>> =
        metas.iter().map(|m| vec![0.0f32; m.size]).collect();
    let plan = SyncPlan::streaming(h, j_parts, &[0, 1, 1, 2, 2], 3);
    let topo = TopologySpec::Flat.build(OpKind::Dense);
    let nc = NoCompression;
    let op = CollectiveOp::new(&nc, OpKind::Dense);
    let mut comm = CommStats::default();
    let mut queue: Vec<RefPending> = Vec::new();

    for step in 1..=3 * h {
        drift(&mut workers, step);
        ref_apply(step, &mut queue, eta, mu, &mut u, &mut theta, &mut workers,
                  &mut comm);
        let mut due = plan.due_tensors(step);
        due.sort_unstable(); // the engine reduces in ascending tensor order
        if due.is_empty() {
            continue;
        }
        let k = workers.len();
        let tensors = due
            .iter()
            .map(|&ti| {
                let mut bufs: Vec<Vec<f32>> = workers
                    .iter()
                    .map(|w| muloco::util::sub(&theta[ti], &w.params[ti]))
                    .collect();
                let trace =
                    topo.reduce_mean(&mut bufs, &op, metas[ti].rows, metas[ti].cols);
                (ti, bufs.into_iter().next().unwrap(), trace.stats_for(k))
            })
            .collect();
        queue.push(RefPending { apply_step: step + tau, tensors });
    }
    ref_apply(u64::MAX, &mut queue, eta, mu, &mut u, &mut theta, &mut workers,
              &mut comm);
    let params = workers.iter().map(|w| w.params.clone()).collect();
    (theta, params, comm)
}

/// tau > stride (H/J): several boundaries are in flight for the same
/// group at once.  The engine's numbers must pin to the longhand
/// delayed-apply reference bit-for-bit — sequential and parallel — and
/// the pending queue must actually hold more than one boundary.
#[test]
fn overlap_tau_beyond_stride_matches_delayed_apply_reference() {
    let corpus = Corpus::new(64, 3);
    let (j_parts, h) = (2usize, 8u64); // boundaries every stride = 4 steps
    for tau in [5u64, 6] {
        let want = delayed_apply_reference(&corpus, j_parts, h, tau);
        for parallel in [false, true] {
            let (mut engine, mut theta, mut workers) = build(
                &corpus, 4, Compression::None, false, j_parts, h,
                TopologySpec::Flat, tau);
            let mut comm = CommStats::default();
            let mut max_pending = 0usize;
            for step in 1..=3 * h {
                drift(&mut workers, step);
                engine.sync_step(step, &mut theta, &mut workers, &mut comm,
                                 parallel);
                max_pending = max_pending.max(engine.n_pending());
            }
            engine.flush(&mut theta, &mut workers, &mut comm);
            let params: Vec<Vec<Vec<f32>>> =
                workers.iter().map(|w| w.params.clone()).collect();
            let tag = format!("tau={tau} parallel={parallel}");
            assert!(max_pending >= 2,
                    "tau > stride must overlap boundaries ({tag}): \
                     max in flight {max_pending}");
            assert_eq!(want.0, theta, "theta diverged from reference: {tag}");
            assert_eq!(want.1, params, "workers diverged from reference: {tag}");
            assert_eq!(want.2, comm, "comm diverged from reference: {tag}");
        }
    }
}
