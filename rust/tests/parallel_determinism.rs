//! Determinism regression tests for the parallel execution engine.
//!
//! Contract under test: running the K inner loops on the WorkerPool's
//! scoped threads and the per-tensor sync reduce across threads yields
//! results bit-for-bit identical to the sequential reference path
//! (`TrainConfig::parallel = false`).
//!
//! The SyncEngine tests run without compiled artifacts (the engine is
//! decoupled from the session); the end-to-end train() comparison runs
//! on whichever backend `Session::load` selects — the native backend
//! on the default build, so nothing here skips anymore.

use muloco::compress::{Compression, ErrorFeedback, QuantMode};
use muloco::collectives::CommStats;
use muloco::coordinator::{train, Method, NesterovOuter, SyncEngine, SyncPlan,
                          SyncTensorMeta, Worker};
use muloco::data::Corpus;
use muloco::util::rng::Rng;

/// Synthetic tensor geometry: two matrices + three vectors.
fn metas() -> Vec<SyncTensorMeta> {
    vec![
        SyncTensorMeta::from_shape(&[8, 16], 128),
        SyncTensorMeta::from_shape(&[64], 64),
        SyncTensorMeta::from_shape(&[16, 4], 64),
        SyncTensorMeta::from_shape(&[32], 32),
        SyncTensorMeta::from_shape(&[96], 96),
    ]
}

fn rand_theta(rng: &mut Rng, metas: &[SyncTensorMeta]) -> Vec<Vec<f32>> {
    metas
        .iter()
        .map(|m| (0..m.size).map(|_| rng.normal_f32()).collect())
        .collect()
}

/// Build an engine + K workers over `corpus`, all from one seed.
fn build<'c>(
    corpus: &'c Corpus,
    k: usize,
    compression: Compression,
    ef: bool,
    j_parts: usize,
    h: u64,
) -> (SyncEngine, Vec<Vec<f32>>, Vec<Worker<'c>>) {
    let metas = metas();
    let mut rng = Rng::new(99);
    let theta = rand_theta(&mut rng, &metas);
    let workers: Vec<Worker<'c>> = (0..k)
        .map(|w| {
            // each worker starts from theta plus its own deterministic drift
            let params: Vec<Vec<f32>> = theta
                .iter()
                .map(|t| t.iter().map(|x| x + 0.01 * rng.normal_f32()).collect())
                .collect();
            Worker::new(params, Vec::new(), corpus.shard(w as u64),
                        ErrorFeedback::new(metas.len(), 0.9))
        })
        .collect();
    let sizes: Vec<usize> = metas.iter().map(|m| m.size).collect();
    let outer = NesterovOuter::new(0.7, 0.9, &sizes);
    let plan = if j_parts <= 1 {
        SyncPlan::dense(h, metas.len())
    } else {
        // partition ids roughly mirroring the 3-way layer split
        let parts = vec![0usize, 1, 1, 2, 2];
        SyncPlan::streaming(h, j_parts, &parts, 3)
    };
    let engine = SyncEngine::from_parts(plan, metas, outer, compression, ef);
    (engine, theta, workers)
}

/// Drift every worker deterministically (stand-in for inner steps).
fn drift(workers: &mut [Worker<'_>], round: u64) {
    for (w, worker) in workers.iter_mut().enumerate() {
        let mut rng = Rng::new(round * 1000 + w as u64);
        for t in worker.params.iter_mut() {
            for x in t.iter_mut() {
                *x += 0.02 * rng.normal_f32();
            }
        }
    }
}

fn run_rounds(
    corpus: &Corpus,
    compression: Compression,
    ef: bool,
    j_parts: usize,
    parallel: bool,
) -> (Vec<Vec<f32>>, Vec<Vec<Vec<f32>>>, CommStats) {
    let h = if j_parts <= 1 { 2 } else { 4 };
    let (mut engine, mut theta, mut workers) =
        build(corpus, 4, compression, ef, j_parts, h);
    let mut comm = CommStats::default();
    for step in 1..=3 * h {
        drift(&mut workers, step);
        engine.sync_step(step, &mut theta, &mut workers, &mut comm, parallel);
    }
    let params = workers.iter().map(|w| w.params.clone()).collect();
    (theta, params, comm)
}

#[test]
fn sync_engine_parallel_matches_sequential() {
    let corpus = Corpus::new(64, 3);
    for (compression, ef) in [
        (Compression::None, false),
        (Compression::Quant { bits: 4, mode: QuantMode::Linear, rowwise: false }, false),
        (Compression::Quant { bits: 8, mode: QuantMode::Linear, rowwise: true }, true),
        (Compression::TopK { frac: 0.25 }, false),
        (Compression::TopK { frac: 0.25 }, true),
    ] {
        for j_parts in [1usize, 2] {
            let (t_seq, p_seq, c_seq) =
                run_rounds(&corpus, compression.clone(), ef, j_parts, false);
            let (t_par, p_par, c_par) =
                run_rounds(&corpus, compression.clone(), ef, j_parts, true);
            assert_eq!(t_seq, t_par,
                       "theta diverged: {compression:?} ef={ef} J={j_parts}");
            assert_eq!(p_seq, p_par,
                       "worker params diverged: {compression:?} ef={ef} J={j_parts}");
            assert_eq!(c_seq, c_par,
                       "comm stats diverged: {compression:?} ef={ef} J={j_parts}");
        }
    }
}

#[test]
fn sync_engine_broadcast_restores_agreement() {
    // after a dense boundary every worker must hold exactly theta
    let corpus = Corpus::new(64, 5);
    let (mut engine, mut theta, mut workers) =
        build(&corpus, 4, Compression::None, false, 1, 1);
    drift(&mut workers, 7);
    let mut comm = CommStats::default();
    engine.sync_step(1, &mut theta, &mut workers, &mut comm, true);
    for w in &workers {
        assert_eq!(w.params, theta);
    }
    // fp32 dense collective moved ring-allreduce bytes for every tensor
    assert!(comm.bytes_per_worker > 0);
    // and the outer momentum picked up the pseudogradient
    assert!(engine.momentum_norm(0) > 0.0);
}

#[test]
fn sync_engine_streaming_only_touches_due_partitions() {
    let corpus = Corpus::new(64, 5);
    let (mut engine, mut theta, mut workers) =
        build(&corpus, 2, Compression::None, false, 2, 4);
    let before = theta.clone();
    drift(&mut workers, 1);
    let mut comm = CommStats::default();
    // step 2 is group 0's slot (stride = H/J = 2): tensors of group 1
    // must be untouched
    engine.sync_step(2, &mut theta, &mut workers, &mut comm, true);
    let due: Vec<usize> = engine.plan.group(0).to_vec();
    for ti in 0..before.len() {
        if due.contains(&ti) {
            assert_ne!(theta[ti], before[ti], "due tensor {ti} not updated");
        } else {
            assert_eq!(theta[ti], before[ti], "idle tensor {ti} was touched");
        }
    }
}

/// End-to-end: a K=8 nano run through the parallel WorkerPool must
/// reproduce the sequential reference bit-for-bit (eval curves, train
/// curves, comm accounting).  Runs un-skipped on the default build:
/// `Session::load` falls back to the native backend, whose kernels fix
/// their accumulation order independent of thread count.
#[test]
fn train_parallel_matches_sequential_reference() {
    let dir = std::path::PathBuf::from("artifacts/nano");
    let sess = muloco::runtime::Session::load(&dir).expect("session");
    let mut cfg = muloco::coordinator::RunSpec::new("nano", Method::Muloco)
        .batch(32)
        .workers(8)
        .steps(10)
        .sync_interval(5)
        .eval_every(5)
        .eval_batches(2)
        .warmup(2)
        .build()
        .unwrap();

    cfg.parallel = false;
    let seq = train(&sess, &cfg).expect("sequential run");
    cfg.parallel = true;
    let par = train(&sess, &cfg).expect("parallel run");

    assert_eq!(seq.eval_curve, par.eval_curve, "eval curves diverged");
    assert_eq!(seq.train_curve, par.train_curve, "train curves diverged");
    assert_eq!(seq.acc_curve, par.acc_curve, "acc curves diverged");
    assert_eq!(seq.comm, par.comm, "comm accounting diverged");
    assert_eq!(seq.tokens, par.tokens);
    assert_eq!(seq.final_params, par.final_params, "final params diverged");
}

/// Same contract under `--precision bf16`: storage rounding is a pure
/// elementwise function applied at fixed points (params-in-flight,
/// activations-at-rest, collective payloads), so it cannot introduce
/// thread-count dependence — the run contract stays BitExact
/// (`tier::contract_for_run`), and parallel must still reproduce the
/// sequential reference byte for byte.
#[test]
fn train_parallel_matches_sequential_reference_bf16() {
    use muloco::runtime::Precision;
    let dir = std::path::PathBuf::from("artifacts/nano");
    let sess = muloco::runtime::Session::load(&dir).expect("session");
    if sess.set_precision(Precision::Bf16).is_err() {
        eprintln!("backend has no bf16 storage mode; skipping");
        return;
    }
    sess.set_precision(Precision::F32).expect("reset precision");
    let mut cfg = muloco::coordinator::RunSpec::new("nano", Method::Muloco)
        .batch(32)
        .workers(8)
        .steps(10)
        .sync_interval(5)
        .eval_every(5)
        .eval_batches(2)
        .warmup(2)
        .precision(Precision::Bf16)
        .build()
        .unwrap();

    cfg.parallel = false;
    let seq = train(&sess, &cfg).expect("sequential bf16 run");
    cfg.parallel = true;
    let par = train(&sess, &cfg).expect("parallel bf16 run");

    assert_eq!(seq.eval_curve, par.eval_curve, "bf16 eval curves diverged");
    assert_eq!(seq.train_curve, par.train_curve, "bf16 train curves diverged");
    assert_eq!(seq.acc_curve, par.acc_curve, "bf16 acc curves diverged");
    assert_eq!(seq.comm, par.comm, "bf16 comm accounting diverged");
    assert_eq!(seq.tokens, par.tokens);
    assert_eq!(seq.final_params, par.final_params, "bf16 final params diverged");
}
