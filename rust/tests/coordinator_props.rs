//! Property-style tests on coordinator/compression invariants.
//!
//! proptest is unavailable offline, so these are seeded randomized
//! property checks over the in-house PRNG (many trials per property,
//! deterministic seeds — failures reproduce exactly).

use muloco::collectives::{quantized_reduce_mean, ring_allreduce_mean,
                          sparse_allgather_mean};
use muloco::compress::{Compressor, ErrorFeedback, NoCompression, QuantMode,
                       Quantizer, TopK};
use muloco::coordinator::{Method, NesterovOuter, TrainConfig};
use muloco::util::rng::Rng;

const TRIALS: usize = 50;

fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32() * scale).collect()
}

#[test]
fn prop_quantization_idempotent_and_bounded() {
    let mut rng = Rng::new(1);
    for trial in 0..TRIALS {
        let n = 1 + rng.below(2000);
        let bits = [2u32, 4, 8][rng.below(3)];
        let mode = if rng.below(2) == 0 { QuantMode::Linear } else { QuantMode::Statistical };
        let q = Quantizer::new(bits, mode, false);
        let orig = rand_vec(&mut rng, n, 1.0 + trial as f32);
        let mut x = orig.clone();
        q.compress(&mut x, 1, n);
        // bounded: quantized values stay within [min, max] of the input
        let lo = orig.iter().copied().fold(f32::INFINITY, f32::min);
        let hi = orig.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        for v in &x {
            assert!(*v >= lo - 1e-5 && *v <= hi + 1e-5, "trial {trial}");
        }
        // idempotent for linear mode (fixed grid)
        if mode == QuantMode::Linear {
            let once = x.clone();
            q.compress(&mut x, 1, n);
            assert_eq!(x, once, "trial {trial}");
        }
        // distinct levels bounded by the codebook size
        let mut distinct = x.clone();
        distinct.sort_by(|a, b| a.partial_cmp(b).unwrap());
        distinct.dedup();
        assert!(distinct.len() <= 1 << bits, "trial {trial}");
    }
}

#[test]
fn prop_error_feedback_conserves_mass() {
    // invariant: communicated + residual == total signal (beta = 1)
    let mut rng = Rng::new(2);
    for trial in 0..TRIALS {
        let n = 1 + rng.below(500);
        let mut ef = ErrorFeedback::new(1, 1.0);
        let mut total_in = vec![0.0f64; n];
        let mut total_sent = vec![0.0f64; n];
        for _ in 0..10 {
            let delta = rand_vec(&mut rng, n, 1.0);
            for (t, d) in total_in.iter_mut().zip(&delta) {
                *t += *d as f64;
            }
            let mut wire = delta.clone();
            ef.compress_with_feedback(0, &mut wire, 1, n, &TopK::new(0.25));
            for (t, w) in total_sent.iter_mut().zip(&wire) {
                *t += *w as f64;
            }
        }
        let resid_norm = ef.residual_norm(0);
        let expect: f64 = total_in.iter().zip(&total_sent)
            .map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        assert!((resid_norm - expect).abs() < 1e-3 * (1.0 + expect),
                "trial {trial}: {resid_norm} vs {expect}");
    }
}

#[test]
fn prop_collectives_agree_and_preserve_mean_when_lossless() {
    let mut rng = Rng::new(3);
    for trial in 0..TRIALS {
        let k = 2 + rng.below(15);
        let n = 1 + rng.below(300);
        let bufs: Vec<Vec<f32>> =
            (0..k).map(|_| rand_vec(&mut rng, n, 2.0)).collect();
        let mut want = vec![0.0f64; n];
        for b in &bufs {
            for (w, x) in want.iter_mut().zip(b) {
                *w += *x as f64 / k as f64;
            }
        }
        for which in 0..3 {
            let mut test = bufs.clone();
            match which {
                0 => { ring_allreduce_mean(&mut test); }
                1 => { quantized_reduce_mean(&mut test, &NoCompression, 1, n); }
                _ => { sparse_allgather_mean(&mut test, &NoCompression, 1, n); }
            }
            for b in &test[1..] {
                assert_eq!(b, &test[0], "trial {trial} collective {which}");
            }
            for (x, w) in test[0].iter().zip(&want) {
                assert!((*x as f64 - w).abs() < 1e-5,
                        "trial {trial} collective {which}");
            }
        }
    }
}

#[test]
fn prop_quantized_collective_error_does_not_grow_with_k() {
    let mut rng = Rng::new(4);
    let q = Quantizer::new(8, QuantMode::Linear, false);
    let n = 512;
    let mut errs = Vec::new();
    for k in [2usize, 4, 8, 16, 32] {
        let bufs: Vec<Vec<f32>> =
            (0..k).map(|_| rand_vec(&mut rng, n, 1.0)).collect();
        let mut want = vec![0.0f64; n];
        for b in &bufs {
            for (w, x) in want.iter_mut().zip(b) {
                *w += *x as f64 / k as f64;
            }
        }
        let mut test = bufs.clone();
        quantized_reduce_mean(&mut test, &q, 1, n);
        let err: f64 = test[0].iter().zip(&want)
            .map(|(a, b)| (*a as f64 - b).abs()).fold(0.0, f64::max);
        errs.push(err);
    }
    let base = errs[0].max(1e-6);
    for (i, e) in errs.iter().enumerate() {
        assert!(*e < 4.0 * base, "K index {i}: {e} vs base {base}");
    }
}

#[test]
fn prop_topk_preserves_top_entries_exactly() {
    let mut rng = Rng::new(5);
    for trial in 0..TRIALS {
        let n = 10 + rng.below(1000);
        let frac = [0.01, 0.1, 0.5][rng.below(3)];
        let orig = rand_vec(&mut rng, n, 1.0);
        let mut x = orig.clone();
        TopK::new(frac).compress(&mut x, 1, n);
        for (a, b) in x.iter().zip(&orig) {
            assert!(*a == 0.0 || a == b, "trial {trial}");
        }
        let kept_min = x.iter().zip(&orig)
            .filter(|(a, _)| **a != 0.0)
            .map(|(_, b)| b.abs())
            .fold(f32::INFINITY, f32::min);
        let dropped_max = x.iter().zip(&orig)
            .filter(|(a, _)| **a == 0.0)
            .map(|(_, b)| b.abs())
            .fold(0.0f32, f32::max);
        assert!(kept_min >= dropped_max, "trial {trial}");
    }
}

#[test]
fn prop_nesterov_linearity_in_pseudogradient() {
    let mut rng = Rng::new(6);
    for _ in 0..TRIALS {
        let n = 1 + rng.below(64);
        let psi = rand_vec(&mut rng, n, 1.0);
        let lr = 0.1 + rng.uniform() * 0.9;
        let mu = rng.uniform() * 0.95;
        let mut o1 = NesterovOuter::new(lr, mu, &[n]);
        let mut t1 = vec![0.0f32; n];
        o1.step_tensor(0, &mut t1, &psi);
        let mut o2 = NesterovOuter::new(lr, mu, &[n]);
        let mut t2 = vec![0.0f32; n];
        let psi2: Vec<f32> = psi.iter().map(|x| 2.0 * x).collect();
        o2.step_tensor(0, &mut t2, &psi2);
        for (a, b) in t1.iter().zip(&t2) {
            assert!((2.0 * a - b).abs() < 1e-4, "{a} {b}");
        }
    }
}

#[test]
fn prop_lr_schedule_bounded_and_terminal() {
    let mut rng = Rng::new(7);
    for _ in 0..TRIALS {
        let mut cfg = TrainConfig::new("nano", Method::Muloco);
        cfg.total_steps = 50 + rng.below(500) as u64;
        cfg.warmup_steps = rng.below(40) as u64 + 1;
        cfg.lr = 0.001 + rng.uniform();
        for step in 0..=cfg.total_steps {
            let lr = cfg.lr_at(step);
            assert!(lr > 0.0 && lr <= cfg.lr * (1.0 + 1e-9));
        }
        let terminal = cfg.lr_at(cfg.total_steps);
        assert!((terminal - cfg.lr * cfg.lr_floor_frac).abs() < 1e-9);
    }
}

#[test]
fn prop_cache_keys_distinguish_configs() {
    use muloco::experiments::cache_key_for_tests as key;
    let base = TrainConfig::new("nano", Method::Muloco);
    let mut variants: Vec<TrainConfig> = Vec::new();
    let mut v = base.clone();
    v.workers = 4;
    variants.push(v);
    let mut v = base.clone();
    v.lr *= 2.0;
    variants.push(v);
    let mut v = base.clone();
    v.seed += 1;
    variants.push(v);
    let mut v = base.clone();
    v.error_feedback = true;
    variants.push(v);
    let mut v = base.clone();
    v.streaming_partitions = 3;
    variants.push(v);
    let mut v = base.clone();
    v.topology = muloco::comm::TopologySpec::Hier { groups: 2 };
    variants.push(v);
    let mut v = base.clone();
    v.topology = muloco::comm::TopologySpec::Ring;
    variants.push(v);
    let mut v = base.clone();
    v.overlap_tau = 2;
    variants.push(v);
    let base_key = key(&base);
    let mut all: Vec<String> = variants.iter().map(key).collect();
    all.push(base_key);
    let unique: std::collections::BTreeSet<&String> = all.iter().collect();
    assert_eq!(unique.len(), all.len(), "cache keys collide: {all:?}");
}
