//! Property tests for the `comm::wire` codec layer.
//!
//! The load-bearing contract: for every compressor configuration,
//! `decode(encode(x))` is **bit-for-bit** identical to the in-place
//! simulated compressor's output on the same input — so the collectives
//! can move real packed bytes without changing value semantics (which
//! is what keeps `tests/parallel_determinism.rs` / `tests/ckpt_resume.rs`
//! green with codecs in the path).  Alongside it, the measured
//! transport size (`encode(..).len()`) is pinned against the analytic
//! `Compressor::wire_bytes` formulas the netsim layer uses.

use muloco::comm::wire::{transport, WireFormat};
use muloco::compress::{
    Compressor, NoCompression, QuantMode, Quantizer, TopK,
};
use muloco::util::rng::Rng;
use muloco::util::round_bf16;

fn payload(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal_f32()).collect()
}

/// NaN-safe, sign-of-zero-safe equality: the contract is bitwise.
fn assert_bits_eq(got: &[f32], want: &[f32], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{tag}[{i}]: {g:?} ({:#010x}) vs {w:?} ({:#010x})",
            g.to_bits(),
            w.to_bits()
        );
    }
}

fn quantizers() -> Vec<Quantizer> {
    let mut qs = Vec::new();
    for mode in [QuantMode::Linear, QuantMode::Statistical] {
        for bits in [2u32, 4, 8] {
            for rowwise in [false, true] {
                qs.push(Quantizer::new(bits, mode, rowwise));
            }
        }
    }
    qs
}

// every (mode, bits, rowwise) x shape: decode(encode(x)) must land on
// exactly the same floats as the in-place quantize-dequantize
#[test]
fn quant_roundtrip_is_bit_identical_to_inplace_compressor() {
    // byte-aligned and ragged (bit-padded) group shapes, plus a
    // single-column rowwise view (group length 1)
    let shapes = [(1usize, 256usize), (8, 32), (1, 7), (5, 13), (6, 1)];
    for q in quantizers() {
        for (seed, &(rows, cols)) in (10u64..).zip(shapes.iter()) {
            let x = payload(rows * cols, seed);
            let mut want = x.clone();
            q.compress(&mut want, rows, cols);
            let codec = q.codec(WireFormat::F32);
            let bytes = codec.encode(&x, rows, cols);
            let got = codec.decode(&bytes, x.len(), rows, cols);
            assert_bits_eq(&got, &want, &format!("{} {rows}x{cols}", q.name()));
        }
    }
}

#[test]
fn measured_quant_bytes_pin_to_wire_bytes_formula() {
    for q in quantizers() {
        // group lengths divisible by 8: the packed stream is exactly
        // the formula (codebooks are padded to 2^bits entries by
        // design, so Statistical pins too)
        for (rows, cols) in [(1usize, 256usize), (8, 32), (4, 64)] {
            let x = payload(rows * cols, 3);
            let measured = q.codec(WireFormat::F32).encode(&x, rows, cols).len();
            assert_eq!(
                measured,
                q.wire_bytes(rows * cols, rows),
                "{} {rows}x{cols}",
                q.name()
            );
        }
        // ragged groups: per-group bit padding rounds each group's code
        // section up to a whole byte, so the measured stream may exceed
        // the formula by at most one byte per group (plus the formula's
        // own floor)
        for (rows, cols) in [(1usize, 7usize), (5, 13), (3, 9)] {
            let x = payload(rows * cols, 4);
            let groups = if rows > 1 { rows } else { 1 };
            let measured = q.codec(WireFormat::F32).encode(&x, rows, cols).len();
            let formula = q.wire_bytes(rows * cols, rows);
            assert!(
                measured >= formula && measured - formula <= groups + 1,
                "{} {rows}x{cols}: measured {measured} vs formula {formula}",
                q.name()
            );
        }
    }
}

#[test]
fn topk_roundtrip_and_measured_bytes() {
    for frac in [0.01f64, 0.1, 0.25, 1.0] {
        let t = TopK::new(frac);
        for (n, seed) in [(1000usize, 21u64), (64, 22), (1, 23)] {
            let x = payload(n, seed);
            let mut want = x.clone();
            t.compress(&mut want, 1, n);
            // f32 value wire: bit-identical to the in-place sparsifier,
            // measured bytes are exactly the formula's 8 per survivor
            let codec = t.codec(WireFormat::F32);
            let bytes = codec.encode(&x, 1, n);
            assert_eq!(bytes.len(), t.wire_bytes(n, 1), "topk{frac} n={n}");
            let got = codec.decode(&bytes, n, 1, n);
            assert_bits_eq(&got, &want, &format!("topk{frac} n={n}"));
            // bf16 value wire: survivor set unchanged, values rounded
            // through the same RNE everything else uses, 6 B/survivor
            let keep = t.wire_bytes(n, 1) / 8;
            let codec16 = t.codec(WireFormat::Bf16);
            let bytes16 = codec16.encode(&x, 1, n);
            assert_eq!(bytes16.len(), 6 * keep, "topk{frac} n={n} bf16");
            let got16 = codec16.decode(&bytes16, n, 1, n);
            let want16: Vec<f32> = want.iter().map(|&v| round_bf16(v)).collect();
            assert_bits_eq(&got16, &want16, &format!("topk{frac} n={n} bf16"));
        }
    }
}

#[test]
fn dense_codecs_roundtrip_and_price_per_word() {
    let x = payload(333, 31);
    let f32c = NoCompression.codec(WireFormat::F32);
    let bytes = f32c.encode(&x, 1, x.len());
    assert_eq!(bytes.len(), 4 * x.len());
    assert_bits_eq(&f32c.decode(&bytes, x.len(), 1, x.len()), &x, "dense f32");

    let bf16c = NoCompression.codec(WireFormat::Bf16);
    let bytes = bf16c.encode(&x, 1, x.len());
    assert_eq!(bytes.len(), 2 * x.len());
    let want: Vec<f32> = x.iter().map(|&v| round_bf16(v)).collect();
    assert_bits_eq(
        &bf16c.decode(&bytes, x.len(), 1, x.len()),
        &want,
        "dense bf16",
    );
}

#[test]
fn degenerate_payloads_roundtrip() {
    let cases: Vec<(Vec<f32>, &str)> = vec![
        (Vec::new(), "empty"),
        (vec![0.0; 48], "all-zero"),
        (vec![1.25; 48], "constant"),
        (vec![-3.5], "single"),
    ];
    let mut codecs: Vec<Box<dyn Compressor>> = quantizers()
        .into_iter()
        .map(|q| Box::new(q) as Box<dyn Compressor>)
        .collect();
    codecs.push(Box::new(TopK::new(0.25)));
    codecs.push(Box::new(NoCompression));
    for c in &codecs {
        for (x, tag) in &cases {
            let (rows, cols) = (1usize, x.len());
            let mut want = x.clone();
            c.compress(&mut want, rows, cols);
            let codec = c.codec(WireFormat::F32);
            let bytes = codec.encode(x, rows, cols);
            let got = codec.decode(&bytes, x.len(), rows, cols);
            assert_bits_eq(&got, &want, &format!("{} {tag}", c.name()));
        }
        // degenerate *row groups*: one constant row inside a live tensor
        let mut x = payload(4 * 16, 41);
        for v in x.iter_mut().take(16) {
            *v = 2.0;
        }
        let mut want = x.clone();
        c.compress(&mut want, 4, 16);
        let codec = c.codec(WireFormat::F32);
        let bytes = codec.encode(&x, 4, 16);
        let got = codec.decode(&bytes, x.len(), 4, 16);
        assert_bits_eq(&got, &want, &format!("{} constant row", c.name()));
    }
}

#[test]
fn transport_moves_measured_bytes_in_place() {
    let q = Quantizer::new(4, QuantMode::Linear, false);
    let codec = q.codec(WireFormat::F32);
    let mut x = payload(512, 51);
    let mut want = x.clone();
    q.compress(&mut want, 1, 512);
    let moved = transport(codec.as_ref(), &mut x, 1, 512);
    assert_eq!(moved, q.wire_bytes(512, 1));
    assert_bits_eq(&x, &want, "transport");
}

// the acceptance bound from the issue: a 2-bit packed dense tensor must
// cost at most 1/8 of its f32 dense transport
#[test]
fn two_bit_dense_is_at_most_one_eighth_of_f32() {
    let n = 4096;
    let x = payload(n, 61);
    for (mode, rowwise, rows, cols) in [
        (QuantMode::Linear, false, 1, n),
        (QuantMode::Linear, true, 64, n / 64),
        (QuantMode::Statistical, false, 1, n),
    ] {
        let q = Quantizer::new(2, mode, rowwise);
        let packed = q.codec(WireFormat::F32).encode(&x, rows, cols).len();
        let dense = NoCompression.codec(WireFormat::F32).encode(&x, rows, cols).len();
        assert!(
            8 * packed <= dense,
            "{}: {packed} * 8 > {dense}",
            q.name()
        );
    }
}
