//! Per-kernel determinism-tier contracts, asserted through the shared
//! harness in `runtime::native::tier`:
//!
//! * Tier::Exact kernels (GEMM microkernel, elementwise sweeps) —
//!   the dispatched active body is bit-for-bit identical to its
//!   always-compiled scalar reference, on the scalar build (trivially)
//!   AND under `--features simd` (the CI nightly job);
//! * Tier::Toleranced kernels (flash SDPA fwd/bwd) — the tiled online-
//!   softmax bodies match the materialized-probability references
//!   within their declared elementwise bounds;
//! * bf16 storage mode — repeat runs are bit-exact (the run contract is
//!   BitExact for BOTH precisions), and the bf16 loss curve tracks the
//!   f32 one within the documented cross-precision tolerance;
//! * variable batch shapes — the native backend derives the batch
//!   dimension from the token-buffer length, so eval tails and uneven
//!   per-worker batches run unpadded.

use std::path::PathBuf;

use muloco::comm::wire;
use muloco::coordinator::{train, Method, RunSpec};
use muloco::runtime::native::gemm::{sgemm, sgemm_rows_scalar};
use muloco::runtime::native::kernels::{
    fused_adamw, fused_adamw_scalar, rmsnorm_bwd, rmsnorm_bwd_scalar,
    rmsnorm_fwd, rmsnorm_fwd_scalar, rope_apply, rope_apply_scalar,
    rope_tables, swiglu_bwd, swiglu_bwd_scalar, swiglu_fwd,
    swiglu_fwd_scalar,
};
use muloco::runtime::native::model::{
    sdpa_flash_bwd, sdpa_flash_fwd, sdpa_materialized_bwd,
    sdpa_materialized_fwd, KV_BLOCK,
};
use muloco::runtime::native::tier::{
    assert_kernel, contract_for_run, tier_of, RunContract, Tier,
    CROSS_PRECISION_LOSS_TOL, KERNEL_TIERS,
};
use muloco::runtime::{Precision, Session};
use muloco::util::rng::Rng;

fn native_session(model: &str) -> Session {
    let dir = PathBuf::from("no-such-artifacts").join(model);
    Session::load(&dir).expect("native session")
}

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32()).collect()
}

// ---------------------------------------------------------------------
// Tier::Exact: the dispatched GEMM vs its scalar reference
// ---------------------------------------------------------------------

/// The public `sgemm` (whatever microkernel the build dispatched,
/// including the threaded path for large shapes) must be bit-for-bit
/// identical to the scalar reference body — the Tier::Exact contract
/// that keeps parallel==sequential and ckpt-resume byte-stable across
/// feature sets.
#[test]
fn sgemm_dispatch_is_bit_exact_vs_scalar_reference() {
    assert_eq!(tier_of("sgemm").tier, Tier::Exact);
    let mut rng = Rng::new(0x7137);
    // shapes cover: microkernel full tiles, row remainders 1-3, column
    // tails, k % 4 tails, KC panel boundaries, and one shape big enough
    // to cross the threading threshold
    for (m, n, k) in [
        (1usize, 1usize, 1usize),
        (4, 16, 8),
        (5, 17, 9),
        (7, 23, 301),
        (8, 24, 260),
        (33, 47, 129),
        (3, 100, 5),
        (200, 200, 150),
    ] {
        let a = randn(&mut rng, m * k);
        let b = randn(&mut rng, k * n);
        let mut got = vec![0f32; m * n];
        sgemm(m, n, k, &a, &b, &mut got);
        let mut reference = vec![0f32; m * n];
        sgemm_rows_scalar(0, m, n, k, &a, &b, &mut reference);
        assert_kernel("sgemm", &got, &reference);
    }
}

// ---------------------------------------------------------------------
// Tier::Exact: elementwise kernels vs their scalar references
// ---------------------------------------------------------------------

#[test]
fn elementwise_kernels_are_bit_exact_vs_scalar_references() {
    let mut rng = Rng::new(0xE1E);
    for n in [1usize, 7, 8, 16, 19, 64, 200] {
        // fused AdamW
        let g = randn(&mut rng, n);
        let p0 = randn(&mut rng, n);
        let m0 = randn(&mut rng, n);
        let v0: Vec<f32> = randn(&mut rng, n).iter().map(|&x| x * x).collect();
        let (mut p, mut m, mut v) = (p0.clone(), m0.clone(), v0.clone());
        fused_adamw(&mut p, &mut m, &mut v, &g, 3.0, 0.01, 0.1);
        let (mut pr, mut mr, mut vr) = (p0, m0, v0);
        fused_adamw_scalar(&mut pr, &mut mr, &mut vr, &g, 3.0, 0.01, 0.1);
        assert_kernel("fused_adamw", &p, &pr);
        assert_kernel("fused_adamw", &m, &mr);
        assert_kernel("fused_adamw", &v, &vr);

        // RMSNorm forward + backward (3 rows of width n)
        let rows = 3;
        let x = randn(&mut rng, rows * n);
        let gain = randn(&mut rng, n);
        let (y, inv_rms) = rmsnorm_fwd(&x, &gain, n, 1e-6);
        let (yr, inv_rms_r) = rmsnorm_fwd_scalar(&x, &gain, n, 1e-6);
        assert_kernel("rmsnorm_fwd", &y, &yr);
        assert_kernel("rmsnorm_fwd", &inv_rms, &inv_rms_r);
        let dy = randn(&mut rng, rows * n);
        let mut dx = vec![0f32; rows * n];
        let mut dg = vec![0f32; n];
        rmsnorm_bwd(&x, &gain, &inv_rms, &dy, n, &mut dx, &mut dg);
        let mut dxr = vec![0f32; rows * n];
        let mut dgr = vec![0f32; n];
        rmsnorm_bwd_scalar(&x, &gain, &inv_rms_r, &dy, n, &mut dxr, &mut dgr);
        assert_kernel("rmsnorm_bwd", &dx, &dxr);
        assert_kernel("rmsnorm_bwd", &dg, &dgr);

        // SwiGLU forward + backward
        let u = randn(&mut rng, n);
        let g_pre = randn(&mut rng, n);
        let mut prod = vec![0f32; n];
        swiglu_fwd(&g_pre, &u, &mut prod);
        let mut prod_r = vec![0f32; n];
        swiglu_fwd_scalar(&g_pre, &u, &mut prod_r);
        assert_kernel("swiglu_fwd", &prod, &prod_r);
        let dprod = randn(&mut rng, n);
        let mut du = vec![0f32; n];
        let mut dgp = vec![0f32; n];
        swiglu_bwd(&g_pre, &u, &dprod, &mut du, &mut dgp);
        let mut dur = vec![0f32; n];
        let mut dgpr = vec![0f32; n];
        swiglu_bwd_scalar(&g_pre, &u, &dprod, &mut dur, &mut dgpr);
        assert_kernel("swiglu_bwd", &du, &dur);
        assert_kernel("swiglu_bwd", &dgp, &dgpr);
    }

    // RoPE over head dims that exercise the 8-lane chunks + tails
    for hd in [8usize, 16, 20] {
        let (b, t, h) = (2usize, 5usize, 2usize);
        let (cos, sin) = rope_tables(t, hd, 10_000.0);
        for inverse in [false, true] {
            let x0 = randn(&mut rng, b * t * h * hd);
            let mut x = x0.clone();
            rope_apply(&mut x, b, t, h, hd, &cos, &sin, inverse);
            let mut xr = x0;
            rope_apply_scalar(&mut xr, b, t, h, hd, &cos, &sin, inverse);
            assert_kernel("rope_apply", &x, &xr);
        }
    }
}

// ---------------------------------------------------------------------
// Tier::Toleranced: flash SDPA vs the materialized reference
// ---------------------------------------------------------------------

/// One attention problem per shape; seq lengths straddle the KV_BLOCK
/// boundary so the online-softmax rescaling across tiles is exercised.
fn sdpa_case(t: usize, seed: u64) -> (usize, usize, usize, usize,
                                      Vec<f32>, Vec<f32>, Vec<f32>) {
    let (b, h, hd) = (2usize, 2usize, 8usize);
    let d = h * hd;
    let mut rng = Rng::new(seed);
    let qr = randn(&mut rng, b * t * d);
    let kr = randn(&mut rng, b * t * d);
    let vh = randn(&mut rng, b * t * d);
    (b, h, hd, d, qr, kr, vh)
}

#[test]
fn flash_sdpa_forward_matches_materialized_within_declared_tier() {
    assert!(matches!(tier_of("sdpa_fwd").tier, Tier::Toleranced { .. }));
    for (i, t) in [1usize, 3, KV_BLOCK - 1, KV_BLOCK, KV_BLOCK + 1, 130]
        .into_iter()
        .enumerate()
    {
        let (b, h, hd, d, qr, kr, vh) = sdpa_case(t, 0x5D9A + i as u64);
        let mut lse = vec![0f32; b * h * t];
        let mut flash = vec![0f32; b * t * d];
        sdpa_flash_fwd(&qr, &kr, &vh, &mut lse, &mut flash, b, t, h, hd, d);
        let mut probs = vec![0f32; b * h * t * t];
        let mut mat = vec![0f32; b * t * d];
        sdpa_materialized_fwd(&qr, &kr, &vh, &mut probs, &mut mat, b, t, h,
                              hd, d);
        assert_kernel("sdpa_fwd", &flash, &mat);
        assert!(lse.iter().all(|x| x.is_finite()), "t={t}: lse not finite");
    }
}

#[test]
fn flash_sdpa_backward_matches_materialized_within_declared_tier() {
    for (i, t) in [1usize, 3, KV_BLOCK, KV_BLOCK + 1, 130].into_iter().enumerate()
    {
        let (b, h, hd, d, qr, kr, vh) = sdpa_case(t, 0xBAD5 + i as u64);
        let mut rng = Rng::new(0xD0 + i as u64);
        let dattn = randn(&mut rng, b * t * d);

        let mut lse = vec![0f32; b * h * t];
        let mut flash_out = vec![0f32; b * t * d];
        sdpa_flash_fwd(&qr, &kr, &vh, &mut lse, &mut flash_out, b, t, h, hd, d);
        let mut dq = vec![0f32; b * t * d];
        let mut dk = vec![0f32; b * t * d];
        let mut dv = vec![0f32; b * t * d];
        sdpa_flash_bwd(&qr, &kr, &vh, &lse, &flash_out, &dattn, &mut dq,
                       &mut dk, &mut dv, b, t, h, hd, d);

        let mut probs = vec![0f32; b * h * t * t];
        let mut mat_out = vec![0f32; b * t * d];
        sdpa_materialized_fwd(&qr, &kr, &vh, &mut probs, &mut mat_out, b, t,
                              h, hd, d);
        let mut dqr_ = vec![0f32; b * t * d];
        let mut dkr_ = vec![0f32; b * t * d];
        let mut dvh_ = vec![0f32; b * t * d];
        sdpa_materialized_bwd(&qr, &kr, &vh, &probs, &dattn, &mut dqr_,
                              &mut dkr_, &mut dvh_, b, t, h, hd, d);

        assert_kernel("sdpa_bwd", &dq, &dqr_);
        assert_kernel("sdpa_bwd", &dk, &dkr_);
        assert_kernel("sdpa_bwd", &dv, &dvh_);
    }
}

// ---------------------------------------------------------------------
// Tier::Exact: wire codec pack/unpack loops vs their scalar references
// ---------------------------------------------------------------------

/// The dispatched wire pack/unpack bodies (whatever `comm::wire`'s
/// public fns resolved to under this build's features) must be
/// bit-for-bit identical to the scalar references — the contract that
/// keeps measured hop bytes AND decoded values identical between the
/// scalar and `--features simd` builds.
#[test]
fn wire_pack_unpack_dispatch_is_bit_exact_vs_scalar_references() {
    for name in ["wire_pack_bf16", "wire_unpack_bf16", "wire_quant_codes",
                 "wire_dequant_codes"] {
        assert_eq!(tier_of(name).tier, Tier::Exact, "{name}");
    }
    let mut rng = Rng::new(0x31BE);
    // lengths straddle the 8-lane boundary; values include the bf16
    // rounding tie cases (exact halves) and negative zero
    for n in [1usize, 7, 8, 9, 64, 200] {
        let mut x = randn(&mut rng, n);
        x[0] = -0.0;
        if n > 2 {
            x[2] = 1.00390625; // exactly between two bf16 neighbours
        }
        let mut packed = Vec::new();
        wire::pack_bf16(&x, &mut packed);
        let mut packed_ref = Vec::new();
        wire::pack_bf16_scalar(&x, &mut packed_ref);
        assert_eq!(packed, packed_ref, "pack_bf16 n={n}");
        let mut back = Vec::new();
        wire::unpack_bf16(&packed, &mut back);
        let mut back_ref = Vec::new();
        wire::unpack_bf16_scalar(&packed_ref, &mut back_ref);
        assert_kernel("wire_unpack_bf16", &back, &back_ref);

        let (lo, scale, lvl) = (-1.5f32, 0.21f32, 15.0f32);
        let mut codes = Vec::new();
        wire::quant_codes(&x, lo, scale, lvl, &mut codes);
        let mut codes_ref = Vec::new();
        wire::quant_codes_scalar(&x, lo, scale, lvl, &mut codes_ref);
        assert_eq!(codes, codes_ref, "quant_codes n={n}");
        let mut deq = Vec::new();
        wire::dequant_codes(&codes, lo, scale, &mut deq);
        let mut deq_ref = Vec::new();
        wire::dequant_codes_scalar(&codes_ref, lo, scale, &mut deq_ref);
        assert_kernel("wire_dequant_codes", &deq, &deq_ref);
    }
}

// ---------------------------------------------------------------------
// Registry sanity
// ---------------------------------------------------------------------

#[test]
fn every_declared_kernel_is_covered_by_this_suite() {
    // every registry entry must be asserted somewhere above; this list
    // is the suite's own coverage ledger — extending KERNEL_TIERS
    // without extending the suite fails here
    let covered = [
        "sgemm", "rmsnorm_fwd", "rmsnorm_bwd", "rope_apply", "swiglu_fwd",
        "swiglu_bwd", "fused_adamw", "newton_schulz", "sdpa_fwd", "sdpa_bwd",
        "wire_pack_bf16", "wire_unpack_bf16", "wire_quant_codes",
        "wire_dequant_codes", "arena_fwd_grad",
    ];
    for kt in KERNEL_TIERS {
        assert!(covered.contains(&kt.name),
                "kernel {:?} declared in KERNEL_TIERS but not covered by \
                 tests/kernel_tiers.rs", kt.name);
    }
    // newton_schulz has no separate reference body (its elementwise
    // sweeps are per-lane maps over the shared GEMM); its exact-tier
    // claim is enforced by muon.rs's closed-form unit tests plus the
    // GEMM assertion above
    assert_eq!(tier_of("newton_schulz").tier, Tier::Exact);
}

// ---------------------------------------------------------------------
// Tier::Exact: the arena-backed step path, warmed vs cold
// ---------------------------------------------------------------------

/// The native backend's step scratch (bump arena + recycled layer
/// slots) is freshly grown on the first call of a thread and reused for
/// every call after.  Where the activation/gradient buffers live must
/// never change the bits: arena slices are zero-filled on alloc and no
/// kernel's accumulation order depends on buffer provenance.  Each
/// `#[test]` runs on its own thread, so the first call here is
/// genuinely cold.
#[test]
fn warmed_arena_step_path_is_bit_exact_vs_cold() {
    assert_eq!(tier_of("arena_fwd_grad").tier, Tier::Exact);
    let sess = native_session("nano");
    let cfg = sess.manifest.config.clone();
    let params = sess.init_params(9).unwrap();
    let tokens: Vec<i32> = (0..cfg.microbatch * cfg.seq_len)
        .map(|i| (i * 17 % cfg.vocab) as i32)
        .collect();
    let (cold_loss, cold_grads) = sess.fwd_grad(&params, &tokens).unwrap();
    let (cold_eval, cold_acc) = sess.eval_step(&params, &tokens).unwrap();
    for rep in 0..3 {
        let (loss, grads) = sess.fwd_grad(&params, &tokens).unwrap();
        assert_eq!(loss.to_bits(), cold_loss.to_bits(), "loss at rep {rep}");
        assert_eq!(grads.len(), cold_grads.len());
        for (g, c) in grads.iter().zip(&cold_grads) {
            assert_kernel("arena_fwd_grad", g, c);
        }
        // the in-place entry point shares the same scratch and bits
        let mut grads_into = Vec::new();
        let loss_into =
            sess.fwd_grad_into(&params, &tokens, &mut grads_into).unwrap();
        assert_eq!(loss_into.to_bits(), cold_loss.to_bits(), "rep {rep}");
        assert_eq!(grads_into, cold_grads);
        let (el, ea) = sess.eval_step(&params, &tokens).unwrap();
        assert_eq!(el.to_bits(), cold_eval.to_bits(), "eval at rep {rep}");
        assert_eq!(ea.to_bits(), cold_acc.to_bits(), "acc at rep {rep}");
    }
}

// ---------------------------------------------------------------------
// bf16 storage mode, end to end
// ---------------------------------------------------------------------

fn nano_spec(precision: Precision) -> RunSpec {
    RunSpec::new("nano", Method::Muloco)
        .batch(16)
        .workers(2)
        .steps(10)
        .sync_interval(5)
        .eval_every(5)
        .eval_batches(2)
        .warmup(2)
        .precision(precision)
}

#[test]
fn bf16_runs_are_bit_exact_and_track_f32_within_documented_tol() {
    let sess = native_session("nano");
    let f32_cfg = nano_spec(Precision::F32).build().unwrap();
    let bf16_cfg = nano_spec(Precision::Bf16).build().unwrap();

    let f = train(&sess, &f32_cfg).expect("f32 run");
    let b1 = train(&sess, &bf16_cfg).expect("bf16 run");
    let b2 = train(&sess, &bf16_cfg).expect("bf16 repeat");

    // repeat-run contract: bf16 rounding is a pure function, so two
    // runs of the same spec agree bit-for-bit (assert_eq, not approx)
    assert_eq!(contract_for_run(Precision::Bf16), RunContract::BitExact);
    assert_eq!(b1.eval_curve, b2.eval_curve);
    assert_eq!(b1.train_curve, b2.train_curve);
    assert_eq!(b1.final_params, b2.final_params);

    // cross-precision: the bf16 curve must track f32 within the
    // documented bound at every recorded point — and actually differ
    // (a bf16 mode that is a no-op would be a wiring bug)
    assert_eq!(f.eval_curve.len(), b1.eval_curve.len());
    for ((sf, lf), (sb, lb)) in f.eval_curve.iter().zip(&b1.eval_curve) {
        assert_eq!(sf, sb);
        assert!(
            (lf - lb).abs() <= CROSS_PRECISION_LOSS_TOL * (1.0 + lf.abs()),
            "step {sf}: bf16 loss {lb} vs f32 {lf} exceeds documented tol"
        );
    }
    assert_ne!(f.train_curve, b1.train_curve,
               "bf16 must actually round storage, not alias the f32 path");
}

// (the bf16 parallel==sequential contract lives with the other engine
// determinism tests in tests/parallel_determinism.rs)

// ---------------------------------------------------------------------
// Variable batch shapes
// ---------------------------------------------------------------------

#[test]
fn native_backend_derives_the_batch_from_the_token_buffer() {
    let sess = native_session("nano");
    let cfg = sess.manifest.config.clone();
    let params = sess.init_params(5).unwrap();
    let mk = |seqs: usize| -> Vec<i32> {
        (0..seqs * cfg.seq_len).map(|i| (i * 31 % cfg.vocab) as i32).collect()
    };
    // any non-empty multiple of seq_len runs — including batches both
    // smaller and larger than the configured microbatch
    for seqs in [1usize, 2, cfg.microbatch, cfg.microbatch + 1] {
        let t = mk(seqs);
        sess.fwd_grad(&params, &t)
            .unwrap_or_else(|e| panic!("fwd_grad at {seqs} seqs: {e}"));
        sess.eval_step(&params, &t)
            .unwrap_or_else(|e| panic!("eval_step at {seqs} seqs: {e}"));
    }
    // a 1-sequence eval agrees with the same sequence inside a batch:
    // rows are independent, so the per-row math is identical
    let two = mk(2);
    let (l1, _) = sess.eval_step(&params, &two[..cfg.seq_len]).unwrap();
    let (l1_b, _) = sess.eval_step(&params, &two[cfg.seq_len..]).unwrap();
    let (l2, _) = sess.eval_step(&params, &two).unwrap();
    let mean = (l1 as f64 + l1_b as f64) / 2.0;
    assert!(
        ((l2 as f64) - mean).abs() < 1e-5,
        "batched eval loss {l2} vs mean of singles {mean}"
    );
    // non-multiples and empty buffers fail loudly
    assert!(sess.fwd_grad(&params, &two[..cfg.seq_len - 1]).is_err());
    assert!(sess.eval_step(&params, &[]).is_err());
}

/// A per-worker batch that is not a microbatch multiple trains through
/// the weighted-tail accumulation path, and stays bit-identical between
/// the parallel and sequential engines.
#[test]
fn uneven_per_worker_batch_trains_and_stays_deterministic() {
    let sess = native_session("nano");
    let spec = || {
        RunSpec::new("nano", Method::Muloco)
            .batch(14) // per worker: 7 = one microbatch of 4 + a tail of 3
            .workers(2)
            .steps(4)
            .sync_interval(2)
            .eval_every(2)
            .eval_batches(1)
            .warmup(1)
    };
    let par = train(&sess, &spec().build().unwrap()).expect("uneven parallel");
    let seq = train(&sess, &spec().parallel(false).build().unwrap())
        .expect("uneven sequential");
    assert_eq!(par.train_curve, seq.train_curve);
    assert_eq!(par.eval_curve, seq.eval_curve);
    assert_eq!(par.final_params, seq.final_params);
    // token accounting counts what was actually consumed
    let seq_len = sess.manifest.config.seq_len as u64;
    assert_eq!(par.tokens, 4 * 14 * seq_len);
}
