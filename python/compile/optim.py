"""L2 inner optimizers: AdamW and Muon, as functional apply-steps.

Both are exported by aot.py as standalone HLO executables
(`apply_adamw`, `apply_muon`) that the rust coordinator calls after
accumulating gradients.  The learning-rate schedule, weight-decay
rescaling (Wang & Aitchison 2024) and step counters live in rust; the
executables take (t, lr, wd) as traced scalars.

Muon (paper §2/§5):
  * momentum  m <- beta*m + g            (beta = 0.9, no dampening)
  * O = NewtonSchulz5(m)                 (the L1 Pallas kernel)
  * per-matrix LR rescale by sqrt(n_cols / n_rows)  for W in R^{m x n}
  * decoupled weight decay (always on, as in the paper)
  * applied to "hidden" 2-D matrices only; embeddings, norms and the
    output head fall back to AdamW (beta1=0.9, beta2=0.99).

State layouts (also written to manifest.json):
  adamw: [m_i for all params] + [v_i for all params]
  muon:  [mom_i for hidden params] + [m_i for adamw-routed params]
         + [v_i for adamw-routed params]
"""

import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.fused_adamw import fused_adamw
from .kernels.newton_schulz import newton_schulz
from .model import param_specs

MUON_BETA = 0.9


def adamw_state_specs(cfg: ModelConfig):
    specs = param_specs(cfg)
    return ([("m." + s.name, s.shape) for s in specs]
            + [("v." + s.name, s.shape) for s in specs])


def muon_param_routing(cfg: ModelConfig):
    """(hidden_indices, adamw_indices) into the flat param list."""
    specs = param_specs(cfg)
    hidden = [i for i, s in enumerate(specs) if s.kind == "hidden"]
    adamw = [i for i, s in enumerate(specs) if s.kind != "hidden"]
    return hidden, adamw


def muon_state_specs(cfg: ModelConfig):
    specs = param_specs(cfg)
    hidden, adamw = muon_param_routing(cfg)
    return ([("mom." + specs[i].name, specs[i].shape) for i in hidden]
            + [("m." + specs[i].name, specs[i].shape) for i in adamw]
            + [("v." + specs[i].name, specs[i].shape) for i in adamw])


def _flatcat(tensors):
    return jnp.concatenate([t.reshape(-1) for t in tensors])


def _split_like(flat, tensors):
    out, off = [], 0
    for t in tensors:
        n = t.size
        out.append(flat[off:off + n].reshape(t.shape))
        off += n
    return out


def apply_adamw(cfg: ModelConfig, params, m, v, grads, t, lr, wd):
    """One AdamW step over the whole flat param list via the L1 kernel.

    All tensors are concatenated into a single flat array so the fused
    kernel makes exactly one sweep (this is also what keeps the lowered
    HLO small: one pallas_call instead of one per tensor).

    Weight decay: norms/embeddings are conventionally excluded from
    decay; the paper's lambda applies to matrices.  We mask decay off
    for 1-D tensors by zeroing their wd contribution per-slice.
    """
    specs = param_specs(cfg)
    pf, mf, vf, gf = map(_flatcat, (params, m, v, grads))
    # build a static 0/1 decay mask: decay 2-D tensors only
    mask = jnp.concatenate([
        jnp.full((s.size,), 1.0 if len(s.shape) == 2 else 0.0, jnp.float32)
        for s in specs
    ])
    # fold the mask in by splitting the update into two fused passes
    # would double bandwidth; instead pre-scale p by the mask trick:
    # theta' = theta - lr*(adam_update + wd*mask*theta).  The kernel
    # applies wd uniformly, so we run it with wd=0 and add the decay
    # term here (still one kernel sweep + one cheap fma).
    pf2, mf2, vf2 = fused_adamw(pf, mf, vf, gf, t, lr, jnp.float32(0.0))
    pf2 = pf2 - lr * wd * mask * pf
    return (_split_like(pf2, params), _split_like(mf2, m),
            _split_like(vf2, v))


def _group_by_shape(indices, tensors):
    """Group tensor indices by shape for batched Newton-Schulz."""
    groups = {}
    for idx, t in zip(indices, tensors):
        groups.setdefault(tuple(t.shape), []).append(idx)
    return groups


def apply_muon(cfg: ModelConfig, params, mom, m, v, grads, t, lr, wd):
    """One MuLoCo inner step: Muon on hidden matrices, AdamW elsewhere.

    Hidden matrices of identical shape are stacked and orthogonalized in
    one batched Newton-Schulz pallas_call per shape group.
    """
    hidden, adamw = muon_param_routing(cfg)
    new_params = list(params)

    # --- Muon branch ---------------------------------------------------
    mom_by_idx = dict(zip(hidden, mom))
    new_mom_by_idx = {}
    grads_by_idx = {i: grads[i] for i in hidden}
    groups = _group_by_shape(hidden, [params[i] for i in hidden])
    for shape, idxs in groups.items():
        g_stack = jnp.stack([grads_by_idx[i] for i in idxs])
        m_stack = jnp.stack([mom_by_idx[i] for i in idxs])
        m_stack = MUON_BETA * m_stack + g_stack  # paper: m = beta*m + g
        o_stack = newton_schulz(m_stack)
        rows, cols = shape
        # paper §5: for W in R^{m x n} rescale LR by sqrt(n/m)
        scale = (cols / rows) ** 0.5
        for j, i in enumerate(idxs):
            new_mom_by_idx[i] = m_stack[j]
            p = params[i]
            new_params[i] = p - lr * scale * o_stack[j] - lr * wd * p
    new_mom = [new_mom_by_idx[i] for i in hidden]

    # --- AdamW branch (embed / head / norms) ---------------------------
    specs = param_specs(cfg)
    a_params = [params[i] for i in adamw]
    a_grads = [grads[i] for i in adamw]
    pf, mf, vf, gf = map(_flatcat, (a_params, m, v, a_grads))
    mask = jnp.concatenate([
        jnp.full((specs[i].size,),
                 1.0 if len(specs[i].shape) == 2 else 0.0, jnp.float32)
        for i in adamw
    ])
    pf2, mf2, vf2 = fused_adamw(pf, mf, vf, gf, t, lr, jnp.float32(0.0))
    pf2 = pf2 - lr * wd * mask * pf
    a_new = _split_like(pf2, a_params)
    for j, i in enumerate(adamw):
        new_params[i] = a_new[j]
    return (new_params, new_mom, _split_like(mf2, m), _split_like(vf2, v))
