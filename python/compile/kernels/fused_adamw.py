"""L1 Pallas kernel: fused AdamW moment + parameter update.

DiLoCo's inner optimizer (and the AdamW half of MuLoCo, which handles
embeddings/norms/head) is a bandwidth-bound elementwise pass over four
equally-shaped arrays (theta, m, v, g).  The fusion does the whole update
in a single sweep so each array streams through VMEM exactly once:

    m' = b1*m + (1-b1)*g
    v' = b2*v + (1-b2)*g*g
    theta' = theta - lr * ( (m'*bc1) / (sqrt(v'*bc2) + eps) + wd*theta )

Bias corrections bc1 = 1/(1-b1^t), bc2 = 1/(1-b2^t) are computed by the
caller (they are scalars shared by every element) and ride in through a
small scalar operand.  On a real TPU this is a VPU kernel with (8, 128)
lanes; under interpret-mode we tile the flattened array in 1-D blocks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# On a real TPU this would be a (8,128)-lane VPU tile loop; under
# interpret-mode each grid point costs a dynamic-update-slice over the
# whole output, so the CPU default is one monolithic block (grid = 1).
# Pass `block` explicitly to exercise the tiled path (python/tests does).
BLOCK = None
# paper §5: beta1 = 0.9, beta2 = 0.99 for all AdamW (inner) experiments
ADAMW_BETA1 = 0.9
ADAMW_BETA2 = 0.99
ADAMW_EPS = 1e-8


def _adamw_kernel(p_ref, m_ref, v_ref, g_ref, sc_ref, po_ref, mo_ref, vo_ref,
                  *, b1, b2, eps):
    lr = sc_ref[0]
    wd = sc_ref[1]
    bc1 = sc_ref[2]
    bc2 = sc_ref[3]
    g = g_ref[...]
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    update = (m * bc1) / (jnp.sqrt(v * bc2) + eps)
    p = p_ref[...]
    po_ref[...] = p - lr * (update + wd * p)
    mo_ref[...] = m
    vo_ref[...] = v


def fused_adamw(p, m, v, g, t, lr, wd,
                *, b1=ADAMW_BETA1, b2=ADAMW_BETA2, eps=ADAMW_EPS,
                block=None, interpret=True):
    """Apply one fused AdamW update to a flat f32 array.

    p, m, v, g: rank-1 arrays of the same length.  t (step, 1-indexed),
    lr, wd: traced scalars.  Returns (p', m', v').
    """
    n0 = p.shape[0]
    block = block or BLOCK or n0
    pad = (-n0) % block
    if pad:
        p, m, v, g = (jnp.pad(x, (0, pad)) for x in (p, m, v, g))
    n = p.shape[0]
    bc1 = 1.0 / (1.0 - b1 ** t)
    bc2 = 1.0 / (1.0 - b2 ** t)
    scalars = jnp.stack([lr, wd, bc1, bc2]).astype(jnp.float32)
    grid = (n // block,)
    blockspec = pl.BlockSpec((block,), lambda i: (i,))
    out = pl.pallas_call(
        functools.partial(_adamw_kernel, b1=b1, b2=b2, eps=eps),
        grid=grid,
        in_specs=[blockspec, blockspec, blockspec, blockspec,
                  pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=[blockspec, blockspec, blockspec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        interpret=interpret,
    )(p, m, v, g, scalars)
    if pad:
        out = [x[:n0] for x in out]
    return tuple(out)
