"""L1 Pallas kernels: Muon's Newton-Schulz orthogonalization hot-spot.

The paper's inner optimizer (Muon, Jordan et al. 2024) orthogonalizes the
momentum matrix with five iterations of the quintic Newton-Schulz map

    X <- a*X + (b*A + c*A@A) @ X,     A = X @ X^T

with (a, b, c) = (3.4445, -4.7750, 2.0315).  The matmul chain is the
compute hot-spot of the optimizer step, so it lives here as Pallas
kernels.

HARDWARE ADAPTATION (see DESIGN.md §3/§7.1): reference GPU Muon kernels
tile for SM shared memory and tensor-core WMMA.  On TPU the same insight
maps to: (i) MXU-shaped tiles staged through VMEM via BlockSpec, (ii)
fp32 accumulation in the output ref across the K grid dimension, and
(iii) fusing the polynomial epilogue (b*A + c*A@A, and the a*X residual)
into the matmul's final K-step so each operand streams HBM->VMEM once.
`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO the rust runtime can
run.  Real-TPU efficiency is estimated analytically in DESIGN.md §7.1.

All kernels are batched over a leading dimension so that same-shaped
hidden matrices across transformer layers are orthogonalized in one
pallas_call (this is what keeps the AOT-lowered HLO small).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Quintic coefficients from Jordan et al. (2024).
NS_COEFFS = (3.4445, -4.7750, 2.0315)
NS_STEPS = 5
_EPS = 1e-7

# Tile sizes.  On a real TPU these would be (128, 128, 256) to match the
# MXU systolic array.  Under interpret-mode on CPU, every grid point
# lowers to a dynamic-update-slice over the *whole* output buffer, so a
# fine grid causes O(grid * |out|) memmove traffic (measured: 40 s per
# optimizer step at d=128 with 32-tiles -> 5 ms with monolithic blocks;
# see EXPERIMENTS.md §Perf).  The CPU default is therefore "one block =
# the whole (padded) operand", grid = (1,1,1,1); pass bm/bn/bk explicitly
# to exercise the TPU-shaped tiling (python/tests does).
BLOCK_M = None  # None = monolithic (full-dim) block
BLOCK_N = None
BLOCK_K = None


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _mm_nt_kernel(x_ref, y_ref, o_ref, *, nk):
    """o[b,i,j] += x[b,i,k] @ y[b,j,k]^T with fp32 accumulation."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


def _poly_mm_kernel(a_ik_ref, a_kj_ref, a_ij_ref, o_ref, *, nk, beta, gamma):
    """Fused polynomial epilogue: o = beta*A + gamma*(A @ A).

    The A_ij tile rides along with the same (i, j) index map as the
    output, so the epilogue costs no extra HBM pass.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        a_ik_ref[...],
        a_kj_ref[...],
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = gamma * o_ref[...] + beta * a_ij_ref[...]


def _residual_mm_kernel(p_ik_ref, x_kj_ref, x_ij_ref, o_ref, *, nk, alpha):
    """Fused residual epilogue: o = alpha*X + P @ X."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        p_ik_ref[...],
        x_kj_ref[...],
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = o_ref[...] + alpha * x_ij_ref[...]


def _grid_specs(nb, m, n, k, bm, bn, bk):
    # batch rides inside the block (a single interpret-mode grid point
    # per (i,j,k) tile); grid covers the matmul tiling only
    del nb
    return (m // bm, n // bn, k // bk)


def matmul_nt(x, y, *, bm=None, bn=None, bk=None, interpret=True):
    """Batched X @ Y^T via the Pallas kernel. x: (B,M,K), y: (B,N,K)."""
    nb, m0, k0 = x.shape
    n0 = y.shape[1]
    bm = bm or BLOCK_M or m0
    bn = bn or BLOCK_N or n0
    bk = bk or BLOCK_K or k0
    x = _pad_to(_pad_to(x, bm, 1), bk, 2)
    y = _pad_to(_pad_to(y, bn, 1), bk, 2)
    _, m, k = x.shape
    n = y.shape[1]
    nk = k // bk
    out = pl.pallas_call(
        functools.partial(_mm_nt_kernel, nk=nk),
        grid=_grid_specs(nb, m, n, k, bm, bn, bk),
        in_specs=[
            pl.BlockSpec((nb, bm, bk), lambda i, j, kk: (0, i, kk)),
            pl.BlockSpec((nb, bn, bk), lambda i, j, kk: (0, j, kk)),
        ],
        out_specs=pl.BlockSpec((nb, bm, bn), lambda i, j, kk: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, m, n), jnp.float32),
        interpret=interpret,
    )(x, y)
    return out[:, :m0, :n0]


def poly_matmul(a, *, beta, gamma, bm=None, bn=None, bk=None, interpret=True):
    """Batched beta*A + gamma*(A @ A) for square A: (B,M,M)."""
    nb, m0, _ = a.shape
    bm = bm or BLOCK_M or m0
    bn = bn or BLOCK_N or m0
    bk = bk or BLOCK_K or m0
    assert bm == bn == bk, "poly_matmul tiles a square matrix uniformly"
    a = _pad_to(_pad_to(a, bm, 1), bm, 2)
    _, m, _ = a.shape
    nk = m // bk
    out = pl.pallas_call(
        functools.partial(_poly_mm_kernel, nk=nk, beta=beta, gamma=gamma),
        grid=_grid_specs(nb, m, m, m, bm, bn, bk),
        in_specs=[
            pl.BlockSpec((nb, bm, bk), lambda i, j, kk: (0, i, kk)),
            pl.BlockSpec((nb, bk, bn), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((nb, bm, bn), lambda i, j, kk: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((nb, bm, bn), lambda i, j, kk: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, m, m), jnp.float32),
        interpret=interpret,
    )(a, a, a)
    return out[:, :m0, :m0]


def residual_matmul(p, x, *, alpha, bm=None, bn=None, bk=None, interpret=True):
    """Batched alpha*X + P @ X. p: (B,M,M), x: (B,M,N)."""
    nb, m0, n0 = x.shape
    bm = bm or BLOCK_M or m0
    bn = bn or BLOCK_N or n0
    bk = bk or BLOCK_K or m0
    # the fused residual needs the X_ij tile to share the output's index
    # map, which requires the row tiling of P and X to agree
    assert bm == bk, "residual_matmul requires bm == bk"
    p = _pad_to(_pad_to(p, bm, 1), bk, 2)
    x = _pad_to(_pad_to(x, bk, 1), bn, 2)
    _, m, k = p.shape
    n = x.shape[2]
    x_out = x
    nk = k // bk
    out = pl.pallas_call(
        functools.partial(_residual_mm_kernel, nk=nk, alpha=alpha),
        grid=_grid_specs(nb, m, n, k, bm, bn, bk),
        in_specs=[
            pl.BlockSpec((nb, bm, bk), lambda i, j, kk: (0, i, kk)),
            pl.BlockSpec((nb, bk, bn), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((nb, bm, bn), lambda i, j, kk: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((nb, bm, bn), lambda i, j, kk: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, m, n), jnp.float32),
        interpret=interpret,
    )(p, x_out, x_out)
    return out[:, :m0, :n0]


def newton_schulz(g, steps=NS_STEPS, coeffs=NS_COEFFS, *, interpret=True):
    """Orthogonalize a batch of matrices g: (B, M, N) via Newton-Schulz.

    Returns an approximation of U V^T where g = U S V^T.  Matches the
    pure-jnp oracle in ref.py to ~1e-4.  Internally works on the
    transpose when M > N so the Gram matrix A = X X^T is the smaller of
    the two possible squares (same trick as the reference CUDA kernels).
    """
    a, b, c = coeffs
    nb, m, n = g.shape
    transpose = m > n
    x = jnp.swapaxes(g, 1, 2) if transpose else g
    x = x / (jnp.linalg.norm(x, axis=(1, 2), keepdims=True) + _EPS)

    def body(_, x):
        gram = matmul_nt(x, x, interpret=interpret)
        poly = poly_matmul(gram, beta=b, gamma=c, interpret=interpret)
        return residual_matmul(poly, x, alpha=a, interpret=interpret)

    x = jax.lax.fori_loop(0, steps, body, x)
    return jnp.swapaxes(x, 1, 2) if transpose else x
