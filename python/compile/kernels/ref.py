"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness references that python/tests checks the Pallas
kernels against (assert_allclose under hypothesis shape/dtype sweeps).
They intentionally use the most direct jnp formulation.
"""

import jax.numpy as jnp

from .newton_schulz import NS_COEFFS, NS_STEPS, _EPS
from .fused_adamw import ADAMW_BETA1, ADAMW_BETA2, ADAMW_EPS


def matmul_nt_ref(x, y):
    """Batched X @ Y^T. x: (B,M,K), y: (B,N,K)."""
    return jnp.einsum("bmk,bnk->bmn", x, y)


def poly_matmul_ref(a, beta, gamma):
    return beta * a + gamma * jnp.einsum("bij,bjk->bik", a, a)


def residual_matmul_ref(p, x, alpha):
    return alpha * x + jnp.einsum("bij,bjk->bik", p, x)


def newton_schulz_ref(g, steps=NS_STEPS, coeffs=NS_COEFFS):
    """Reference quintic Newton-Schulz orthogonalization. g: (B,M,N)."""
    a, b, c = coeffs
    transpose = g.shape[1] > g.shape[2]
    x = jnp.swapaxes(g, 1, 2) if transpose else g
    x = x / (jnp.linalg.norm(x, axis=(1, 2), keepdims=True) + _EPS)
    for _ in range(steps):
        gram = jnp.einsum("bmk,bnk->bmn", x, x)
        poly = b * gram + c * jnp.einsum("bij,bjk->bik", gram, gram)
        x = a * x + jnp.einsum("bij,bjk->bik", poly, x)
    return jnp.swapaxes(x, 1, 2) if transpose else x


def adamw_ref(p, m, v, g, t, lr, wd,
              b1=ADAMW_BETA1, b2=ADAMW_BETA2, eps=ADAMW_EPS):
    """Reference fused-AdamW update on flat arrays."""
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * g * g
    mhat = m / (1.0 - b1 ** t)
    vhat = v / (1.0 - b2 ** t)
    p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    return p, m, v
