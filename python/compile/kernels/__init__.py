"""L1 Pallas kernels for the MuLoCo reproduction (interpret=True on CPU)."""
from .newton_schulz import newton_schulz, matmul_nt, poly_matmul, residual_matmul  # noqa: F401
from .fused_adamw import fused_adamw  # noqa: F401
