"""AOT pipeline: lower the L2/L1 step functions to HLO text artifacts.

Emits HLO *text* (NOT `.serialize()`): jax >= 0.5 emits HloModuleProto
with 64-bit instruction ids which xla_extension 0.5.1 (the version the
published `xla` 0.1.6 crate links) rejects; the text parser reassigns
ids and round-trips cleanly.  See /opt/xla-example/README.md.

Per model config this writes to artifacts/<config>/:
  init.hlo.txt         (seed u32[])                  -> (params...,)
  fwd_grad.hlo.txt     (params..., tokens i32[b,T])  -> (loss, grads...)
  apply_adamw.hlo.txt  (params..., m..., v..., grads..., t, lr, wd)
                                                     -> (params', m', v')
  apply_muon.hlo.txt   (params..., mom..., am..., av..., grads..., t, lr, wd)
                                                     -> (params', mom', am', av')
  eval_step.hlo.txt    (params..., tokens)           -> (loss, acc)
  manifest.json        tensor layout + dims + flops the rust side needs

Python runs ONLY here (build time).  The rust binary is self-contained
once artifacts exist; `make artifacts` is a no-op when inputs are
unchanged.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import CONFIGS
from .model import param_specs, init_params, loss_and_grad, eval_metrics
from .optim import (apply_adamw, apply_muon, adamw_state_specs,
                    muon_state_specs, muon_param_routing)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _specs(shapes, dtype=jnp.float32):
    return [jax.ShapeDtypeStruct(s, dtype) for s in shapes]


def export_config(cfg, out_root):
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    specs = param_specs(cfg)
    p_shapes = [s.shape for s in specs]
    np_ = len(specs)
    tok_spec = jax.ShapeDtypeStruct((cfg.microbatch, cfg.seq_len), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    a_state = adamw_state_specs(cfg)
    mu_state = muon_state_specs(cfg)
    hidden, adamw_idx = muon_param_routing(cfg)
    n_hidden, n_adamw = len(hidden), len(adamw_idx)

    def write(name, fn, arg_specs):
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"  {cfg.name}/{name}: {len(text) / 1e6:.2f} MB HLO text")
        return os.path.basename(path)

    files = {}

    # 1. init(seed) -> params
    def init_fn(seed):
        return tuple(init_params(cfg, seed))
    files["init"] = write(
        "init", init_fn, [jax.ShapeDtypeStruct((), jnp.uint32)])

    # 2. fwd_grad(params..., tokens) -> (loss, grads...)
    def fwd_grad_fn(*args):
        params, tokens = list(args[:np_]), args[np_]
        loss, grads = loss_and_grad(cfg, params, tokens)
        return (loss, *grads)
    files["fwd_grad"] = write(
        "fwd_grad", fwd_grad_fn, _specs(p_shapes) + [tok_spec])

    # 3. apply_adamw(params..., m..., v..., grads..., t, lr, wd)
    def adamw_fn(*args):
        o = 0
        params = list(args[o:o + np_]); o += np_
        m = list(args[o:o + np_]); o += np_
        v = list(args[o:o + np_]); o += np_
        grads = list(args[o:o + np_]); o += np_
        t, lr, wd = args[o], args[o + 1], args[o + 2]
        p2, m2, v2 = apply_adamw(cfg, params, m, v, grads, t, lr, wd)
        return (*p2, *m2, *v2)
    files["apply_adamw"] = write(
        "apply_adamw", adamw_fn,
        _specs(p_shapes) * 4 + [scalar, scalar, scalar])

    # 4. apply_muon(params..., mom..., am..., av..., grads..., t, lr, wd)
    mom_shapes = [specs[i].shape for i in hidden]
    aw_shapes = [specs[i].shape for i in adamw_idx]

    def muon_fn(*args):
        o = 0
        params = list(args[o:o + np_]); o += np_
        mom = list(args[o:o + n_hidden]); o += n_hidden
        am = list(args[o:o + n_adamw]); o += n_adamw
        av = list(args[o:o + n_adamw]); o += n_adamw
        grads = list(args[o:o + np_]); o += np_
        t, lr, wd = args[o], args[o + 1], args[o + 2]
        p2, mom2, m2, v2 = apply_muon(cfg, params, mom, am, av, grads,
                                      t, lr, wd)
        return (*p2, *mom2, *m2, *v2)
    files["apply_muon"] = write(
        "apply_muon", muon_fn,
        _specs(p_shapes) + _specs(mom_shapes) + _specs(aw_shapes) * 2
        + _specs(p_shapes) + [scalar, scalar, scalar])

    # 5. eval_step(params..., tokens) -> (loss, acc)
    def eval_fn(*args):
        params, tokens = list(args[:np_]), args[np_]
        return eval_metrics(cfg, params, tokens)
    files["eval_step"] = write(
        "eval_step", eval_fn, _specs(p_shapes) + [tok_spec])

    manifest = {
        "config": cfg.to_dict(),
        "params": [
            {"name": s.name, "shape": list(s.shape), "size": s.size,
             "kind": s.kind, "partition": s.partition}
            for s in specs
        ],
        "adamw_state": [
            {"name": n, "shape": list(sh)} for n, sh in a_state],
        "muon_state": [
            {"name": n, "shape": list(sh)} for n, sh in mu_state],
        "muon_hidden_indices": hidden,
        "muon_adamw_indices": adamw_idx,
        "executables": files,
        "scalar_inputs": ["t", "lr", "wd"],
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  {cfg.name}: manifest written "
          f"({manifest['config']['param_count']} params)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="nano",
                    help="config name, comma list, or 'all'")
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    names = (list(CONFIGS) if args.config == "all"
             else args.config.split(","))
    for name in names:
        print(f"exporting {name} ...")
        export_config(CONFIGS[name], args.out_dir)


if __name__ == "__main__":
    main()
