"""L2 JAX model: Gemma3-style decoder-only transformer.

Mirrors the paper's architecture (§5, Table 1): SwiGLU FFN, QK-norm,
RoPE, RMSNorm both *before and after* the attention/FFN blocks (the
"additional RMS normalization layers before residual connections"), and
an untied output head.

Parameters are carried as a flat list whose order is defined by
`param_specs(cfg)`; the same order is what aot.py writes into
manifest.json and what the rust runtime marshals.  Kinds route the
optimizer: "hidden" tensors get Muon in MuLoCo, everything else
(embed/head/norm) gets AdamW, exactly as in the paper.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .configs import ModelConfig


@dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple
    kind: str  # "embed" | "head" | "norm" | "hidden"
    partition: int  # streaming-DiLoCo partition id (layer thirds)

    @property
    def size(self):
        n = 1
        for s in self.shape:
            n *= s
        return n


def param_specs(cfg: ModelConfig):
    """The canonical flat parameter layout (order matters everywhere)."""
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
    specs = [ParamSpec("embed", (cfg.vocab, d), "embed", 0)]
    for i in range(cfg.n_layers):
        # partition layers into thirds for streaming DiLoCo (Douillard
        # et al. 2025); embed joins the first, head the last partition.
        part = min(2, 3 * i // max(1, cfg.n_layers))
        p = f"l{i}."
        specs += [
            ParamSpec(p + "norm_att_in", (d,), "norm", part),
            ParamSpec(p + "wq", (d, d), "hidden", part),
            ParamSpec(p + "wk", (d, d), "hidden", part),
            ParamSpec(p + "wv", (d, d), "hidden", part),
            ParamSpec(p + "qnorm", (hd,), "norm", part),
            ParamSpec(p + "knorm", (hd,), "norm", part),
            ParamSpec(p + "wo", (d, d), "hidden", part),
            ParamSpec(p + "norm_att_out", (d,), "norm", part),
            ParamSpec(p + "norm_ffn_in", (d,), "norm", part),
            ParamSpec(p + "wg", (d, f), "hidden", part),
            ParamSpec(p + "wu", (d, f), "hidden", part),
            ParamSpec(p + "wd", (f, d), "hidden", part),
            ParamSpec(p + "norm_ffn_out", (d,), "norm", part),
        ]
    specs += [
        ParamSpec("norm_f", (d,), "norm", 2),
        ParamSpec("head", (d, cfg.vocab), "head", 2),
    ]
    return specs


def init_params(cfg: ModelConfig, seed):
    """Initialize the flat parameter list from a (traced) uint32 seed."""
    key = jax.random.PRNGKey(seed)
    specs = param_specs(cfg)
    keys = jax.random.split(key, len(specs))
    params = []
    for spec, k in zip(specs, keys):
        if spec.kind == "norm":
            params.append(jnp.ones(spec.shape, jnp.float32))
        elif spec.kind == "embed":
            params.append(0.02 * jax.random.normal(k, spec.shape, jnp.float32))
        else:
            fan_in = spec.shape[0]
            std = fan_in ** -0.5
            # residual-output projections get the 1/sqrt(2L) shrink
            if spec.name.endswith(("wo", "wd")):
                std /= (2.0 * cfg.n_layers) ** 0.5
            params.append(std * jax.random.normal(k, spec.shape, jnp.float32))
    return params


def _unflatten(cfg: ModelConfig, flat):
    specs = param_specs(cfg)
    assert len(flat) == len(specs), (len(flat), len(specs))
    return dict(zip((s.name for s in specs), flat))


def _rmsnorm(x, scale, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def _rope(x, theta):
    """x: (B, T, H, hd) -> rotated; standard half-split RoPE."""
    b, t, h, hd = x.shape
    half = hd // 2
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * freqs  # (T, half)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def forward(cfg: ModelConfig, flat_params, tokens):
    """tokens: (B, T) int32 -> logits (B, T, vocab)."""
    p = _unflatten(cfg, flat_params)
    eps = cfg.norm_eps
    b, t = tokens.shape
    h, hd = cfg.n_heads, cfg.head_dim
    x = p["embed"][tokens] * (cfg.d_model ** 0.5)
    mask = jnp.tril(jnp.ones((t, t), jnp.bool_))
    for i in range(cfg.n_layers):
        l = f"l{i}."
        # attention block: pre-norm, QK-norm, RoPE, causal SDPA, post-norm
        xin = _rmsnorm(x, p[l + "norm_att_in"], eps)
        q = (xin @ p[l + "wq"]).reshape(b, t, h, hd)
        k = (xin @ p[l + "wk"]).reshape(b, t, h, hd)
        v = (xin @ p[l + "wv"]).reshape(b, t, h, hd)
        q = _rmsnorm(q, p[l + "qnorm"], eps)
        k = _rmsnorm(k, p[l + "knorm"], eps)
        q = _rope(q, cfg.rope_theta)
        k = _rope(k, cfg.rope_theta)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (hd ** 0.5)
        scores = jnp.where(mask[None, None], scores, -1e30)
        att = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, t, cfg.d_model)
        out = out @ p[l + "wo"]
        x = x + _rmsnorm(out, p[l + "norm_att_out"], eps)
        # SwiGLU block with pre+post norm
        xin = _rmsnorm(x, p[l + "norm_ffn_in"], eps)
        gate = jax.nn.silu(xin @ p[l + "wg"])
        up = xin @ p[l + "wu"]
        out = (gate * up) @ p[l + "wd"]
        x = x + _rmsnorm(out, p[l + "norm_ffn_out"], eps)
    x = _rmsnorm(x, p["norm_f"], eps)
    return x @ p["head"]


def loss_fn(cfg: ModelConfig, flat_params, tokens):
    """Mean next-token cross-entropy over (B, T-1) positions."""
    logits = forward(cfg, flat_params, tokens)[:, :-1]
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def loss_and_grad(cfg: ModelConfig, flat_params, tokens):
    return jax.value_and_grad(lambda ps: loss_fn(cfg, ps, tokens))(flat_params)


def eval_metrics(cfg: ModelConfig, flat_params, tokens):
    """Returns (mean CE loss, next-token top-1 accuracy)."""
    logits = forward(cfg, flat_params, tokens)[:, :-1]
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == targets).astype(jnp.float32))
    return loss, acc
