"""Model scale ladder for the MuLoCo reproduction.

The paper (Table 1) trains Gemma3-style transformers from 150M to 15.2B
parameters at 20 tokens-per-parameter.  This environment is a single-core
CPU host, so we reproduce the *ladder structure* (six scales, fixed
depth/width ratios, 20-TPP budgets configurable at the launcher) at a
miniature scale.  Dims follow the paper's ratios: ffn ~ 2.75 * d_model,
head_dim fixed, QK-norm + pre/post RMSNorm + SwiGLU, untied head.

Every config here is AOT-lowered by aot.py into its own artifact
directory; the rust coordinator picks configs by name.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    paper_scale: str  # which paper row this rung mirrors
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    seq_len: int
    microbatch: int  # per-executable batch (global batch = n_micro * this)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        per_layer = (
            4 * d * d  # wq, wk, wv, wo
            + 3 * d * f  # wg, wu, wd
            + 4 * d  # four RMSNorm scales
            + 2 * self.head_dim  # qk-norm scales
        )
        return v * d + self.n_layers * per_layer + d + d * v

    def flops_per_token(self) -> float:
        """~6N fwd+bwd plus the attention quadratic term."""
        n_matmul = self.param_count() - 2 * self.vocab * self.d_model
        attn = 12 * self.n_layers * self.d_model * self.seq_len
        return 6.0 * (n_matmul + self.vocab * self.d_model * 2) + attn

    def to_dict(self):
        d = asdict(self)
        d["head_dim"] = self.head_dim
        d["param_count"] = self.param_count()
        d["flops_per_token"] = self.flops_per_token()
        return d


def _ffn(d: int) -> int:
    # paper ratio d_ff ~ 2.75 * d_model, rounded to a multiple of 8
    return int(round(2.75 * d / 8)) * 8


# The six-rung ladder mirroring Table 1 (150M..15B), scaled to CPU budget.
# head_dim = 16 throughout (paper: 128).
CONFIGS = {
    "nano": ModelConfig("nano", "150M", 2, 32, 2, _ffn(32), 256, 64, 4),
    "micro": ModelConfig("micro", "416M", 3, 48, 3, _ffn(48), 256, 64, 4),
    "tiny": ModelConfig("tiny", "914M", 4, 64, 4, _ffn(64), 256, 64, 4),
    "small": ModelConfig("small", "1.76B", 5, 96, 6, _ffn(96), 256, 64, 4),
    "med": ModelConfig("med", "3.07B", 6, 128, 8, _ffn(128), 256, 64, 4),
    "big": ModelConfig("big", "15.2B", 8, 192, 12, _ffn(192), 512, 64, 4),
    # end-to-end example config (largest practical on this host)
    "e2e": ModelConfig("e2e", "e2e-demo", 6, 256, 16, _ffn(256), 2048, 128, 4),
}

# The five extensively-swept rungs (the paper sweeps 150M..3.1B and holds
# out 15B); `big` plays the 15B "extrapolate, don't sweep" role.
LADDER = ["nano", "micro", "tiny", "small", "med"]
HOLDOUT = "big"
