"""Inner-optimizer tests: AdamW + Muon apply-steps (L2 over L1)."""

import numpy as np
import jax.numpy as jnp

from compile.configs import CONFIGS
from compile import model as M
from compile import optim as O
from compile.kernels import ref

CFG = CONFIGS["nano"]


def _setup(seed=0):
    rng = np.random.default_rng(seed)
    params = M.init_params(CFG, jnp.uint32(seed))
    grads = [jnp.asarray(rng.normal(scale=1e-2, size=p.shape)
                         .astype(np.float32)) for p in params]
    return rng, params, grads


def _zeros_like(params):
    return [jnp.zeros_like(p) for p in params]


def test_adamw_matches_per_tensor_reference():
    _, params, grads = _setup(0)
    m, v = _zeros_like(params), _zeros_like(params)
    t, lr, wd = jnp.float32(1), jnp.float32(1e-2), jnp.float32(0.1)
    p2, m2, v2 = O.apply_adamw(CFG, params, m, v, grads, t, lr, wd)
    for spec, p, g, pp, mm, vv in zip(M.param_specs(CFG), params, grads,
                                      p2, m2, v2):
        wd_eff = 0.1 if len(spec.shape) == 2 else 0.0
        pe, me, ve = ref.adamw_ref(p.reshape(-1), jnp.zeros(p.size),
                                   jnp.zeros(p.size), g.reshape(-1),
                                   1.0, 1e-2, wd_eff)
        np.testing.assert_allclose(pp.reshape(-1), pe, rtol=2e-5, atol=1e-7,
                                   err_msg=spec.name)
        np.testing.assert_allclose(mm.reshape(-1), me, rtol=2e-5, atol=1e-8)
        np.testing.assert_allclose(vv.reshape(-1), ve, rtol=2e-5, atol=1e-9)


def test_adamw_no_decay_on_norms():
    """Norm scales must not be pulled toward zero by weight decay."""
    _, params, _ = _setup(1)
    grads = _zeros_like(params)  # zero grads isolate the decay term
    m, v = _zeros_like(params), _zeros_like(params)
    p2, _, _ = O.apply_adamw(CFG, params, m, v, grads, jnp.float32(1),
                             jnp.float32(1e-2), jnp.float32(0.5))
    for spec, p, pp in zip(M.param_specs(CFG), params, p2):
        if spec.kind == "norm":
            np.testing.assert_array_equal(np.asarray(p), np.asarray(pp))
        elif len(spec.shape) == 2:
            assert float(jnp.abs(pp - p).max()) > 0, spec.name


def test_muon_routing_matches_manifest():
    hidden, adamw = O.muon_param_routing(CFG)
    specs = M.param_specs(CFG)
    assert sorted(hidden + adamw) == list(range(len(specs)))
    for i in hidden:
        assert specs[i].kind == "hidden" and len(specs[i].shape) == 2
    for i in adamw:
        assert specs[i].kind != "hidden"


def test_muon_step_is_orthonormal_scaled():
    """With zero momentum and wd=0, the Muon delta on a hidden matrix is
    lr * sqrt(n/m) * NS(g): its singular values should be ~lr*sqrt(n/m)."""
    _, params, grads = _setup(2)
    hidden, adamw = O.muon_param_routing(CFG)
    mom = [jnp.zeros(params[i].shape) for i in hidden]
    am = [jnp.zeros(params[i].shape) for i in adamw]
    av = [jnp.zeros(params[i].shape) for i in adamw]
    lr = 1e-2
    p2, mom2, _, _ = O.apply_muon(CFG, params, mom, am, av, grads,
                                  jnp.float32(1), jnp.float32(lr),
                                  jnp.float32(0.0))
    i = hidden[0]
    rows, cols = params[i].shape
    delta = np.asarray(params[i] - p2[i])
    s = np.linalg.svd(delta, compute_uv=False)
    expect = lr * (cols / rows) ** 0.5
    assert 0.5 * expect < s.mean() < 1.5 * expect, (s.mean(), expect)
    # momentum accumulator picked up the gradient
    np.testing.assert_allclose(np.asarray(mom2[0]),
                               np.asarray(grads[i]), rtol=1e-6)


def test_muon_adamw_branch_matches_adamw():
    """Non-hidden params must evolve exactly like plain AdamW."""
    _, params, grads = _setup(3)
    hidden, adamw = O.muon_param_routing(CFG)
    mom = [jnp.zeros(params[i].shape) for i in hidden]
    am = [jnp.zeros(params[i].shape) for i in adamw]
    av = [jnp.zeros(params[i].shape) for i in adamw]
    t, lr, wd = jnp.float32(1), jnp.float32(1e-2), jnp.float32(0.1)
    p_mu, _, _, _ = O.apply_muon(CFG, params, mom, am, av, grads, t, lr, wd)
    m, v = _zeros_like(params), _zeros_like(params)
    p_aw, _, _ = O.apply_adamw(CFG, params, m, v, grads, t, lr, wd)
    for i in adamw:
        np.testing.assert_allclose(np.asarray(p_mu[i]), np.asarray(p_aw[i]),
                                   rtol=1e-5, atol=1e-8)


def test_muon_momentum_accumulation():
    """m_t = beta*m_{t-1} + g_t (paper formulation, no dampening)."""
    _, params, grads = _setup(4)
    hidden, adamw = O.muon_param_routing(CFG)
    mom = [jnp.asarray(np.random.default_rng(5).normal(
        size=params[i].shape).astype(np.float32)) for i in hidden]
    am = [jnp.zeros(params[i].shape) for i in adamw]
    av = [jnp.zeros(params[i].shape) for i in adamw]
    _, mom2, _, _ = O.apply_muon(CFG, params, mom, am, av, grads,
                                 jnp.float32(1), jnp.float32(1e-2),
                                 jnp.float32(0.0))
    for j, i in enumerate(hidden):
        want = O.MUON_BETA * mom[j] + grads[i]
        np.testing.assert_allclose(np.asarray(mom2[j]), np.asarray(want),
                                   rtol=1e-6)


def test_muon_reduces_loss():
    rng = np.random.default_rng(6)
    params = M.init_params(CFG, jnp.uint32(6))
    toks = jnp.asarray(rng.integers(
        0, CFG.vocab, size=(CFG.microbatch, CFG.seq_len)).astype(np.int32))
    hidden, adamw = O.muon_param_routing(CFG)
    mom = [jnp.zeros(params[i].shape) for i in hidden]
    am = [jnp.zeros(params[i].shape) for i in adamw]
    av = [jnp.zeros(params[i].shape) for i in adamw]
    l0 = float(M.loss_fn(CFG, params, toks))
    for t in range(1, 6):
        _, grads = M.loss_and_grad(CFG, params, toks)
        params, mom, am, av = O.apply_muon(
            CFG, params, mom, am, av, grads,
            jnp.float32(t), jnp.float32(0.05), jnp.float32(0.0))
    l1 = float(M.loss_fn(CFG, params, toks))
    assert l1 < l0, (l0, l1)


def test_state_spec_shapes():
    specs = M.param_specs(CFG)
    a = O.adamw_state_specs(CFG)
    assert len(a) == 2 * len(specs)
    mu = O.muon_state_specs(CFG)
    hidden, adamw = O.muon_param_routing(CFG)
    assert len(mu) == len(hidden) + 2 * len(adamw)
