"""AOT export tests: manifest consistency + HLO text properties."""

import json
import os

import pytest

from compile.configs import CONFIGS
from compile import aot
from compile.model import param_specs
from compile.optim import adamw_state_specs, muon_state_specs


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.export_config(CONFIGS["nano"], str(out))
    return os.path.join(str(out), "nano")


def test_all_files_written(exported):
    names = ["init", "fwd_grad", "apply_adamw", "apply_muon", "eval_step"]
    for n in names:
        path = os.path.join(exported, f"{n}.hlo.txt")
        assert os.path.exists(path), n
        text = open(path).read()
        assert text.startswith("HloModule"), n
        assert "ENTRY" in text, n


def test_manifest_matches_specs(exported):
    man = json.load(open(os.path.join(exported, "manifest.json")))
    cfg = CONFIGS["nano"]
    specs = param_specs(cfg)
    assert len(man["params"]) == len(specs)
    for ms, s in zip(man["params"], specs):
        assert ms["name"] == s.name
        assert tuple(ms["shape"]) == tuple(s.shape)
        assert ms["size"] == s.size
        assert ms["kind"] == s.kind
    assert len(man["adamw_state"]) == len(adamw_state_specs(cfg))
    assert len(man["muon_state"]) == len(muon_state_specs(cfg))
    assert man["config"]["param_count"] == cfg.param_count()
    assert man["scalar_inputs"] == ["t", "lr", "wd"]


def test_manifest_routing_indices(exported):
    man = json.load(open(os.path.join(exported, "manifest.json")))
    n = len(man["params"])
    both = sorted(man["muon_hidden_indices"] + man["muon_adamw_indices"])
    assert both == list(range(n))
    for i in man["muon_hidden_indices"]:
        assert man["params"][i]["kind"] == "hidden"


def test_hlo_no_serialized_proto(exported):
    """Interchange must be HLO text (xla_extension 0.5.1 gotcha)."""
    for f in os.listdir(exported):
        if f.endswith(".hlo.txt"):
            head = open(os.path.join(exported, f)).read(200)
            assert head.startswith("HloModule"), f


def test_parameter_counts_in_hlo(exported):
    """fwd_grad must declare n_params + 1 (tokens) entry parameters."""
    cfg = CONFIGS["nano"]
    n = len(param_specs(cfg))
    text = open(os.path.join(exported, "fwd_grad.hlo.txt")).read()
    entry = text.split("ENTRY")[1]
    assert entry.count("parameter(") == n + 1
