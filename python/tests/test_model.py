"""L2 model tests: shapes, invariances, and learning signal."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import CONFIGS, ModelConfig
from compile import model as M

CFG = CONFIGS["nano"]


def _tokens(rng, cfg, b=None):
    b = b or cfg.microbatch
    return jnp.asarray(
        rng.integers(0, cfg.vocab, size=(b, cfg.seq_len)).astype(np.int32))


def test_param_specs_cover_param_count():
    for name in ("nano", "micro", "tiny"):
        cfg = CONFIGS[name]
        total = sum(s.size for s in M.param_specs(cfg))
        assert total == cfg.param_count(), name


def test_param_specs_partitions_are_balanced_thirds():
    cfg = CONFIGS["tiny"]
    parts = {0: 0, 1: 0, 2: 0}
    for s in M.param_specs(cfg):
        parts[s.partition] += s.size
    total = sum(parts.values())
    for p, sz in parts.items():
        assert sz > 0.1 * total, (p, sz, total)


def test_init_deterministic_in_seed():
    p1 = M.init_params(CFG, jnp.uint32(7))
    p2 = M.init_params(CFG, jnp.uint32(7))
    p3 = M.init_params(CFG, jnp.uint32(8))
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(a, b)
    assert any(float(jnp.abs(a - b).max()) > 0
               for a, b in zip(p1, p3) if a.ndim == 2)


def test_forward_shapes_and_finite():
    rng = np.random.default_rng(0)
    params = M.init_params(CFG, jnp.uint32(0))
    toks = _tokens(rng, CFG)
    logits = M.forward(CFG, params, toks)
    assert logits.shape == (CFG.microbatch, CFG.seq_len, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform():
    """Fresh model's CE should be close to log(vocab)."""
    rng = np.random.default_rng(1)
    params = M.init_params(CFG, jnp.uint32(1))
    loss = float(M.loss_fn(CFG, params, _tokens(rng, CFG)))
    assert abs(loss - np.log(CFG.vocab)) < 1.0, loss


def test_causality():
    """Changing a future token must not change past logits."""
    rng = np.random.default_rng(2)
    params = M.init_params(CFG, jnp.uint32(2))
    toks = _tokens(rng, CFG, b=1)
    logits1 = M.forward(CFG, params, toks)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % CFG.vocab)
    logits2 = M.forward(CFG, params, toks2)
    np.testing.assert_allclose(logits1[0, :-1], logits2[0, :-1],
                               rtol=1e-5, atol=1e-5)


def test_grads_cover_all_params_and_are_finite():
    rng = np.random.default_rng(3)
    params = M.init_params(CFG, jnp.uint32(3))
    loss, grads = M.loss_and_grad(CFG, params, _tokens(rng, CFG))
    assert len(grads) == len(params)
    for spec, g in zip(M.param_specs(CFG), grads):
        assert g.shape == tuple(spec.shape)
        assert bool(jnp.all(jnp.isfinite(g))), spec.name
        assert float(jnp.abs(g).max()) > 0, spec.name


def test_sgd_reduces_loss():
    """A few plain-SGD steps on one batch must reduce its loss."""
    rng = np.random.default_rng(4)
    params = M.init_params(CFG, jnp.uint32(4))
    toks = _tokens(rng, CFG)
    l0, _ = M.loss_and_grad(CFG, params, toks)
    for _ in range(5):
        _, grads = M.loss_and_grad(CFG, params, toks)
        params = [p - 0.5 * g for p, g in zip(params, grads)]
    l1, _ = M.loss_and_grad(CFG, params, toks)
    assert float(l1) < float(l0)


def test_eval_metrics_consistent_with_loss():
    rng = np.random.default_rng(5)
    params = M.init_params(CFG, jnp.uint32(5))
    toks = _tokens(rng, CFG)
    loss, acc = M.eval_metrics(CFG, params, toks)
    np.testing.assert_allclose(float(loss), float(M.loss_fn(CFG, params, toks)),
                               rtol=1e-6)
    assert 0.0 <= float(acc) <= 1.0


def test_rmsnorm_scale_invariance():
    x = jnp.asarray(np.random.default_rng(6).normal(size=(4, 8)),
                    jnp.float32)
    y1 = M._rmsnorm(x, jnp.ones(8), 1e-6)
    y2 = M._rmsnorm(3.0 * x, jnp.ones(8), 1e-6)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)


def test_rope_preserves_norm():
    x = jnp.asarray(np.random.default_rng(7).normal(size=(1, 16, 2, 16)),
                    jnp.float32)
    y = M._rope(x, 10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(y, axis=-1),
                               rtol=1e-5, atol=1e-5)


def test_rope_relative_position():
    """RoPE inner products depend only on relative offsets."""
    hd = 16
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(size=(hd,)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(hd,)), jnp.float32)
    t = 12
    qb = jnp.broadcast_to(q, (1, t, 1, hd))
    kb = jnp.broadcast_to(k, (1, t, 1, hd))
    qr, kr = M._rope(qb, 10000.0), M._rope(kb, 10000.0)
    dots = jnp.einsum("thd,uhd->tu", qr[0].transpose(0, 1, 2), kr[0])
    # same relative offset -> same dot product, along diagonals
    d1 = float(dots[3, 5]); d2 = float(dots[7, 9])
    np.testing.assert_allclose(d1, d2, rtol=1e-4)


@pytest.mark.parametrize("name", ["nano", "micro"])
def test_all_ladder_configs_forward(name):
    cfg = CONFIGS[name]
    rng = np.random.default_rng(9)
    params = M.init_params(cfg, jnp.uint32(0))
    toks = _tokens(rng, cfg, b=2)
    loss = M.loss_fn(cfg, params, toks)
    assert bool(jnp.isfinite(loss))
