"""Kernel-vs-reference correctness: the CORE L1 signal.

Hypothesis sweeps shapes (and block sizes) of the Pallas kernels and
checks them against the pure-jnp oracles in kernels/ref.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (newton_schulz, matmul_nt, poly_matmul,
                             residual_matmul, fused_adamw)
from compile.kernels import ref
from compile.kernels.newton_schulz import NS_COEFFS

SETTINGS = dict(max_examples=20, deadline=None)


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@settings(**SETTINGS)
@given(b=st.integers(1, 3), m=st.integers(1, 40), n=st.integers(1, 40),
       k=st.integers(1, 40), seed=st.integers(0, 2**31 - 1))
def test_matmul_nt_matches_ref(b, m, n, k, seed):
    rng = np.random.default_rng(seed)
    x, y = _rand(rng, b, m, k), _rand(rng, b, n, k)
    got = matmul_nt(x, y)
    want = ref.matmul_nt_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(b=st.integers(1, 3), m=st.integers(1, 40),
       beta=st.floats(-5, 5), gamma=st.floats(-5, 5),
       seed=st.integers(0, 2**31 - 1))
def test_poly_matmul_matches_ref(b, m, beta, gamma, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, b, m, m)
    got = poly_matmul(a, beta=beta, gamma=gamma)
    want = ref.poly_matmul_ref(a, beta, gamma)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(b=st.integers(1, 3), m=st.integers(1, 33), n=st.integers(1, 50),
       alpha=st.floats(-5, 5), seed=st.integers(0, 2**31 - 1))
def test_residual_matmul_matches_ref(b, m, n, alpha, seed):
    rng = np.random.default_rng(seed)
    p, x = _rand(rng, b, m, m), _rand(rng, b, m, n)
    got = residual_matmul(p, x, alpha=alpha)
    want = ref.residual_matmul_ref(p, x, alpha)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(b=st.integers(1, 3), m=st.integers(2, 40), n=st.integers(2, 40),
       seed=st.integers(0, 2**31 - 1))
def test_newton_schulz_matches_ref(b, m, n, seed):
    rng = np.random.default_rng(seed)
    g = _rand(rng, b, m, n)
    got = newton_schulz(g)
    want = ref.newton_schulz_ref(g)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("shape", [(8, 8), (16, 48), (48, 16), (33, 7)])
def test_newton_schulz_orthogonalizes(shape):
    """NS output should approximate U V^T: singular values near 1."""
    rng = np.random.default_rng(0)
    g = _rand(rng, 2, *shape)
    o = newton_schulz(g)
    s = jnp.linalg.svd(o[0], compute_uv=False)
    # quintic NS converges loosely (by design, per Jordan et al.);
    # singular values land in ~[0.7, 1.3]
    assert float(s.max()) < 1.6
    assert float(s.min()) > 0.4


def test_newton_schulz_preserves_singular_vectors():
    """NS(g) should align with the exact orthogonal factor U V^T."""
    rng = np.random.default_rng(1)
    g = _rand(rng, 1, 12, 12)
    o = np.asarray(newton_schulz(g))[0]
    u, _, vt = np.linalg.svd(np.asarray(g)[0])
    exact = u @ vt
    cos = (o * exact).sum() / (np.linalg.norm(o) * np.linalg.norm(exact))
    # quintic NS oscillates around the polar factor by design; ~0.97+
    # alignment after 5 steps matches the reference implementation
    assert cos > 0.95


@pytest.mark.parametrize("blocks", [(8, 8, 8), (16, 16, 16), (64, 64, 64)])
def test_matmul_block_size_invariance(blocks):
    bm, bn, bk = blocks
    rng = np.random.default_rng(2)
    x, y = _rand(rng, 2, 24, 40), _rand(rng, 2, 18, 40)
    got = matmul_nt(x, y, bm=bm, bn=bn, bk=bk)
    want = ref.matmul_nt_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(n=st.integers(1, 5000), t=st.integers(1, 100),
       lr=st.floats(1e-5, 1e-1), wd=st.floats(0.0, 0.3),
       seed=st.integers(0, 2**31 - 1))
def test_fused_adamw_matches_ref(n, t, lr, wd, seed):
    rng = np.random.default_rng(seed)
    p, m, g = (_rand(rng, n) for _ in range(3))
    v = jnp.abs(_rand(rng, n))
    tt, lrr, wdd = jnp.float32(t), jnp.float32(lr), jnp.float32(wd)
    got = fused_adamw(p, m, v, g, tt, lrr, wdd)
    want = ref.adamw_ref(p, m, v, g, float(t), lr, wd)
    for gg, ww in zip(got, want):
        np.testing.assert_allclose(gg, ww, rtol=2e-5, atol=2e-6)


def test_fused_adamw_block_boundary():
    """Tiled path: exactly-one-block and one-past-block sizes."""
    BLOCK = 256
    rng = np.random.default_rng(3)
    for n in (BLOCK, BLOCK + 1, 2 * BLOCK - 1):
        p, m, g = (_rand(rng, n) for _ in range(3))
        v = jnp.abs(_rand(rng, n))
        got = fused_adamw(p, m, v, g, jnp.float32(1), jnp.float32(1e-2),
                          jnp.float32(0.1), block=BLOCK)
        want = ref.adamw_ref(p, m, v, g, 1.0, 1e-2, 0.1)
        for gg, ww in zip(got, want):
            np.testing.assert_allclose(gg, ww, rtol=2e-5, atol=2e-6)


def test_newton_schulz_zero_matrix():
    """Zero momentum must not NaN (Frobenius-norm epsilon guard)."""
    g = jnp.zeros((1, 8, 8), jnp.float32)
    o = newton_schulz(g)
    assert bool(jnp.all(jnp.isfinite(o)))
