//! End-to-end driver: trains the `e2e` transformer (the largest
//! practical config on this host; the paper's full pipeline at
//! miniature scale) for a few hundred steps with MuLoCo K=4 and logs
//! the full loss curve against DiLoCo and both DP baselines.
//!
//! This is the EXPERIMENTS.md §E2E run:
//!
//!   make artifacts && cargo run --release --example train_e2e -- [--model e2e] [--steps N]
//!
//! Pass `--model nano --steps 60` for a quick check; defaults exercise
//! the real workload.

use muloco::coordinator::{train, Method, RunSpec};
use muloco::metrics::RunLogger;
use muloco::runtime::Session;
use muloco::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let model = args.get_or("model", "e2e");
    let steps: u64 = args.get_parse("steps", 300)?;
    let batch: usize = args.get_parse("batch", 32)?;
    args.finish()?;

    let sess = Session::load(&std::path::Path::new("artifacts").join(&model))?;
    let m = &sess.manifest.config;
    println!(
        "e2e driver: {} — {} params, {} layers, d={}, vocab={}, seq={}",
        m.name, m.param_count, m.n_layers, m.d_model, m.vocab, m.seq_len
    );

    let logger = RunLogger::new("e2e")?;
    let mut headline = Vec::new();
    for (label, method, k) in [
        ("muloco-k4", Method::Muloco, 4usize),
        ("diloco-k4", Method::Diloco, 4),
        ("dp-muon", Method::DpMuon, 1),
        ("dp-adamw", Method::DpAdamw, 1),
    ] {
        let mut spec = RunSpec::new(&model, method)
            .batch(batch)
            .steps(steps)
            .sync_interval(15)
            .eval_every(15)
            .eval_batches(4)
            .warmup(steps / 10);
        if method.is_local_update() {
            spec = spec.workers(k);
        }
        let cfg = spec.build()?;
        println!("\n=== {label}: K={} H={} B={} steps={}",
                 cfg.workers, cfg.sync_interval, cfg.global_batch, steps);
        let t0 = std::time::Instant::now();
        let r = train(&sess, &cfg)?;
        for (step, loss) in &r.eval_curve {
            println!("  step {step:>5}: eval loss {loss:.4}");
        }
        println!(
            "  -> final smoothed {:.4} | acc {:.3} | {:.1}s wall | {:.1} MB/worker comm",
            r.smoothed_final, r.final_acc,
            t0.elapsed().as_secs_f64(),
            r.comm.bytes_per_worker as f64 / 1e6
        );
        logger.log(label, &r)?;
        headline.push((label, r.smoothed_final));
    }

    println!("\n=== summary (smoothed final eval loss) ===");
    for (label, loss) in &headline {
        println!("  {label:<10} {loss:.4}");
    }
    println!("curves in results/e2e/runs/*.csv");
    Ok(())
}
