//! Compression lab: takes a LIVE pseudogradient from a short MuLoCo
//! run and compares every compressor's reconstruction error, wire
//! size, and the all-to-all vs error-compounding-ring collectives.
//!
//!   cargo run --release --example compression_lab

use muloco::collectives::{quantized_reduce_mean,
                          ring_quantized_reduce_compounding};
use muloco::compress::{Compressor, QuantMode, Quantizer, TopK};
use muloco::coordinator::{branch_capture, dp_warmstart, Method};
use muloco::runtime::Session;

fn main() -> anyhow::Result<()> {
    let sess = Session::load(std::path::Path::new("artifacts/nano"))?;
    // produce a real pseudogradient: warmstart DP-Muon, branch K=8
    println!("generating a live pseudogradient (DP warmstart + K=8 branch)...");
    let ckpt = dp_warmstart(&sess, Method::DpMuon, 30, 64, 0.1, 0.1, 7)?;
    let cap = branch_capture(&sess, Method::Muloco, &ckpt, 8, 10, 64,
                             0.1, 0.1, 7)?;

    // flatten all hidden-tensor pseudogradients into one vector
    let psi: Vec<f32> = cap.pseudograd.iter().flatten().copied().collect();
    let n = psi.len();
    println!("pseudogradient: {n} values over {} hidden tensors\n",
             cap.n_tensors());

    let compressors: Vec<Box<dyn Compressor>> = vec![
        Box::new(Quantizer::new(8, QuantMode::Linear, false)),
        Box::new(Quantizer::new(4, QuantMode::Linear, false)),
        Box::new(Quantizer::new(2, QuantMode::Linear, false)),
        Box::new(Quantizer::new(4, QuantMode::Statistical, false)),
        Box::new(Quantizer::new(2, QuantMode::Statistical, false)),
        Box::new(TopK::new(0.10)),
        Box::new(TopK::new(0.01)),
    ];

    println!("{:<16} {:>10} {:>10} {:>14}", "compressor", "wire KB",
             "ratio", "rel L2 error");
    for c in &compressors {
        let mut x = psi.clone();
        let bytes = c.compress(&mut x, 1, n);
        let err: f64 = x.iter().zip(&psi)
            .map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>().sqrt();
        let norm: f64 = psi.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        println!(
            "{:<16} {:>10.1} {:>9.1}x {:>14.5}",
            c.name(), bytes as f64 / 1e3,
            (4 * n) as f64 / bytes as f64,
            err / norm
        );
    }

    // the collective story: all-to-all reduce-scatter avoids the
    // per-hop requantization error of a naive ring (paper §2)
    println!("\ncollective comparison at 4-bit, K=16 (mean rel error):");
    let q = Quantizer::new(4, QuantMode::Linear, false);
    let deltas: Vec<Vec<f32>> = cap.worker_delta.iter()
        .map(|wd| wd.iter().flatten().copied().collect())
        .collect();
    let mut exact = vec![0.0f32; n];
    for d in &deltas {
        for (e, x) in exact.iter_mut().zip(d) {
            *e += x / deltas.len() as f32;
        }
    }
    let rel_err = |bufs: &[Vec<f32>]| -> f64 {
        let e: f64 = bufs[0].iter().zip(&exact)
            .map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>().sqrt();
        let nn: f64 = exact.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        e / nn
    };
    let mut a2a = deltas.clone();
    quantized_reduce_mean(&mut a2a, &q, 1, n);
    let mut ring = deltas.clone();
    ring_quantized_reduce_compounding(&mut ring, &q, 1, n);
    println!("  all-to-all + all-gather (2 quantizations): {:.5}", rel_err(&a2a));
    println!("  naive ring (dequant-reduce-requant per hop): {:.5}", rel_err(&ring));
    Ok(())
}
