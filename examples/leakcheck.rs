//! Memory-leak regression check for the PJRT runtime path.
//!
//! The xla crate's `execute::<Literal>` leaks its C-side input buffer
//! conversions (~input bytes per call); the Session therefore uses
//! `buffer_from_host_buffer` + `execute_b` with rust-owned buffers.
//! This example hammers fwd_grad/apply_muon and prints VmRSS — flat
//! RSS means the fix holds (EXPERIMENTS.md §Perf iteration 2).

fn main() -> anyhow::Result<()> {
    let sess = muloco::runtime::Session::load(std::path::Path::new("artifacts/nano"))?;
    let params = sess.init_params(0)?;
    let cfg = &sess.manifest.config;
    let tokens: Vec<i32> = (0..cfg.microbatch * cfg.seq_len).map(|i| (i % cfg.vocab) as i32).collect();
    let rss = || {
        let s = std::fs::read_to_string("/proc/self/status").unwrap();
        s.lines().find(|l| l.starts_with("VmRSS")).unwrap().to_string()
    };
    println!("start {}", rss());
    for i in 0..1000 {
        let _ = sess.fwd_grad(&params, &tokens)?;
        if i % 250 == 249 { println!("fwd {} {}", i+1, rss()); }
    }
    let state = sess.zero_muon_state();
    let (_, grads) = sess.fwd_grad(&params, &tokens)?;
    for i in 0..500 {
        let _ = sess.apply_muon(&params, &state, &grads, 1.0, 0.01, 0.0)?;
        if i % 125 == 124 { println!("muon {} {}", i+1, rss()); }
    }
    let astate = sess.zero_adamw_state();
    for i in 0..300 {
        let _ = sess.apply_adamw(&params, &astate, &grads, 1.0, 0.01, 0.0)?;
        if i % 100 == 99 { println!("adamw {} {}", i+1, rss()); }
    }
    for i in 0..600 {
        let _ = sess.eval_step(&params, &tokens)?;
        if i % 200 == 199 { println!("eval {} {}", i+1, rss()); }
    }
    for i in 0..300 {
        let _ = sess.init_params(i as u32)?;
        if i % 100 == 99 { println!("init {} {}", i+1, rss()); }
    }
    Ok(())
}
