//! Bandwidth planner: given a model size, worker count, sync interval
//! and compression, print projected wall-clock and utilization across
//! link speeds — the Fig 16 / Fig 20 machinery as a user-facing tool.
//!
//!   cargo run --release --example bandwidth_planner -- \
//!       --params 3.1e9 --workers 16 --sync-interval 30 \
//!       --compression-bits 4 --step-secs 2.85 --steps 30000

use muloco::netsim::{CommPattern, SystemProfile, GBIT};
use muloco::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["dp"])?;
    let params: f64 = args.get_parse("params", 3.1e9)?;
    let workers: usize = args.get_parse("workers", 16)?;
    let h: u64 = args.get_parse("sync-interval", 30)?;
    let bits: u32 = args.get_parse("compression-bits", 32)?;
    let step_secs: f64 = args.get_parse("step-secs", 2.85)?;
    let opt_secs: f64 = args.get_parse("opt-secs", 0.03)?;
    let steps: u64 = args.get_parse("steps", 30_000)?;
    let dp = args.flag("dp");
    args.finish()?;

    let param_bytes = 4.0 * params;
    let profile = SystemProfile::flat(
        step_secs,
        opt_secs,
        param_bytes,
        param_bytes * bits as f64 / 32.0,
        workers,
        if dp {
            CommPattern::EveryStep
        } else {
            CommPattern::EveryH { h }
        },
    );

    println!(
        "plan: {params:.2e} params, K={workers}, {} sync, {bits}-bit wire, \
         {step_secs:.2}s compute/step, {steps} steps",
        if dp { "per-step (DP)".to_string() } else { format!("every H={h}") }
    );
    println!("\n{:>12} {:>14} {:>12}", "bandwidth", "train hours", "utilization");
    for bw_gbit in [1.0, 10.0, 100.0, 400.0, 1600.0, 6400.0, 12800.0] {
        let bw = bw_gbit * GBIT;
        println!(
            "{:>9} Gb {:>14.1} {:>11.1}%",
            bw_gbit,
            profile.training_hours(steps, bw),
            100.0 * profile.utilization(bw)
        );
    }
    println!(
        "\nbandwidth for 99% utilization: {:.2} Gbit/s",
        profile.bandwidth_for_utilization(0.99) / GBIT
    );
    Ok(())
}
