//! Quickstart: load the AOT artifacts, train MuLoCo with K=4 workers
//! for a few outer rounds on the synthetic corpus, and print the loss
//! table.  Run with:
//!
//!   make artifacts && cargo run --release --example quickstart

use muloco::coordinator::{train, Method, RunSpec};
use muloco::runtime::Session;

fn main() -> anyhow::Result<()> {
    let sess = Session::load(std::path::Path::new("artifacts/nano"))?;
    println!(
        "loaded {} ({} params) on {}",
        sess.manifest.config.name,
        sess.manifest.config.param_count,
        sess.platform()
    );

    let cfg = RunSpec::new("nano", Method::Muloco)
        .batch(32)
        .workers(4)
        .steps(60)
        .sync_interval(15)
        .eval_every(15)
        .build()?;

    println!(
        "training MuLoCo: K={} workers, H={} local steps, {} total steps",
        cfg.workers, cfg.sync_interval, cfg.total_steps
    );
    let result = train(&sess, &cfg)?;
    println!("\n step | eval loss | eval acc");
    for ((step, loss), (_, acc)) in
        result.eval_curve.iter().zip(&result.acc_curve)
    {
        println!(" {step:>4} | {loss:>9.4} | {acc:.3}");
    }
    println!(
        "\nsmoothed final loss (App-F estimator): {:.4}",
        result.smoothed_final
    );
    println!(
        "communicated {:.2} MB per worker over {} tokens",
        result.comm.bytes_per_worker as f64 / 1e6,
        result.tokens
    );
    Ok(())
}
