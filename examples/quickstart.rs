//! Quickstart: load the AOT artifacts, train MuLoCo with K=4 workers
//! for a few outer rounds on the synthetic corpus, and print the loss
//! table.  Run with:
//!
//!   make artifacts && cargo run --release --example quickstart

use muloco::coordinator::{train, Method, TrainConfig};
use muloco::runtime::Session;

fn main() -> anyhow::Result<()> {
    let sess = Session::load(std::path::Path::new("artifacts/nano"))?;
    println!(
        "loaded {} ({} params) on {}",
        sess.manifest.config.name,
        sess.manifest.config.param_count,
        sess.platform()
    );

    let mut cfg = TrainConfig::new("nano", Method::Muloco);
    cfg.global_batch = 32;
    cfg = cfg.tuned_outer(4)?;
    cfg.total_steps = 60;
    cfg.sync_interval = 15;
    cfg.eval_every = 15;

    println!(
        "training MuLoCo: K={} workers, H={} local steps, {} total steps",
        cfg.workers, cfg.sync_interval, cfg.total_steps
    );
    let result = train(&sess, &cfg)?;
    println!("\n step | eval loss | eval acc");
    for ((step, loss), (_, acc)) in
        result.eval_curve.iter().zip(&result.acc_curve)
    {
        println!(" {step:>4} | {loss:>9.4} | {acc:.3}");
    }
    println!(
        "\nsmoothed final loss (App-F estimator): {:.4}",
        result.smoothed_final
    );
    println!(
        "communicated {:.2} MB per worker over {} tokens",
        result.comm.bytes_per_worker as f64 / 1e6,
        result.tokens
    );
    Ok(())
}
